//! Minimal, dependency-free unix signal plumbing for the graceful-drain
//! paths (`repro serve` / `repro worker --listen` / `repro drive`) and
//! the process-backend job watchdog.
//!
//! The crate vendors no libc bindings, so the two syscall wrappers the
//! drain/deadline machinery needs — `signal(2)` to install a flag-setting
//! handler and `kill(2)` to deliver a signal to a child by pid — are
//! hand-declared `extern "C"` symbols resolved from the platform libc.
//! Everything is `#[cfg(unix)]`; on other targets the helpers are inert
//! no-ops (install does nothing, [`drain_requested`] is always false,
//! [`send`] reports failure), so callers never need their own gates.
//!
//! The handler itself only stores into a process-global `AtomicBool`
//! (the one operation that is unconditionally async-signal-safe); the
//! long-running loops poll [`drain_requested`] and run their own
//! teardown — cancel pending work, let in-flight jobs persist, unlink
//! unix sockets via the normal `Drop` path — then exit with
//! [`EXIT_DRAINED`] so supervisors can tell a drained exit from a crash.
//!
//! Note on restartable syscalls: glibc's `signal()` installs BSD
//! semantics (`SA_RESTART`), so a blocking `accept(2)` is *not*
//! interrupted by the signal.  The drain loops therefore never rely on
//! `EINTR`: `repro serve` self-dials its own endpoint to unblock accept,
//! and `repro worker --listen` runs a tiny monitor thread that does the
//! same when the flag flips.

use std::sync::atomic::{AtomicBool, Ordering};

/// Exit code for a clean signal-initiated drain (distinct from both a
/// success and a crash; `75` = BSD sysexits' `EX_TEMPFAIL`, "transient
/// condition, retry later" — which is exactly what a drained daemon is).
pub const EXIT_DRAINED: i32 = 75;

pub const SIGINT: i32 = 2;
pub const SIGKILL: i32 = 9;
pub const SIGTERM: i32 = 15;

static DRAIN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" {
    fn signal(signum: i32, handler: usize) -> usize;
    fn kill(pid: i32, sig: i32) -> i32;
}

#[cfg(unix)]
extern "C" fn on_drain_signal(_sig: i32) {
    DRAIN.store(true, Ordering::SeqCst);
}

/// Install the SIGTERM/SIGINT drain handler.  Idempotent; after this,
/// [`drain_requested`] flips to true on the first of either signal (the
/// default kill-the-process disposition is replaced, so a supervisor's
/// TERM becomes a request, not a kill).  No-op off unix.
pub fn install_drain_handler() {
    #[cfg(unix)]
    unsafe {
        let handler: extern "C" fn(i32) = on_drain_signal;
        signal(SIGTERM, handler as usize);
        signal(SIGINT, handler as usize);
    }
}

/// Has a drain signal arrived since [`install_drain_handler`]?
pub fn drain_requested() -> bool {
    DRAIN.load(Ordering::SeqCst)
}

/// Test hook: flip the drain flag by hand (what the handler does).
pub fn request_drain() {
    DRAIN.store(true, Ordering::SeqCst);
}

/// Deliver `sig` to `pid` (true on success).  Used by the process
/// backend's deadline watchdog (SIGKILL to a hung child — `Child::kill`
/// needs `&mut Child`, which the blocked reader thread owns) and by the
/// drain tests.  Always false off unix.
pub fn send(pid: u32, sig: i32) -> bool {
    #[cfg(unix)]
    {
        unsafe { kill(pid as i32, sig) == 0 }
    }
    #[cfg(not(unix))]
    {
        let _ = (pid, sig);
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drain_flag_starts_clear_and_latches() {
        install_drain_handler();
        // the flag is process-global; other tests in this binary do not
        // touch it, so observing the latch here is race-free
        request_drain();
        assert!(drain_requested());
    }

    #[cfg(unix)]
    #[test]
    fn send_reports_failure_for_an_impossible_pid() {
        // pid 0 would signal our own process group; use an unlikely huge
        // pid instead, which kill(2) rejects with ESRCH
        assert!(!send(u32::MAX / 2, 0));
    }
}
