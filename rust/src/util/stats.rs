//! Small summary-statistics helpers shared by sweeps and benches.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Half-width of the 95% confidence interval for the mean (normal approx;
/// the paper's Fig 5 shaded bands).
pub fn ci95_half(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Index of the minimum value (ties -> first).
pub fn argmin(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x < xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(argmin(&[3.0, 1.0, 2.0, 1.0]), 1);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..1000).map(|i| (i % 10) as f64).collect();
        assert!(ci95_half(&b) < ci95_half(&a));
    }
}
