//! Dependency-free substrates: JSON, RNG, summary stats, property
//! testing, bench timing, CSV/plot output. (The environment is offline,
//! so these are in-tree rather than crates — see Cargo.toml.)

pub mod bench;
pub mod hash;
pub mod json;
pub mod plot;
pub mod prop;
pub mod rng;
pub mod signal;
pub mod stats;

pub use json::{write_json_num, write_json_str, Json};
pub use rng::Rng;
