//! Mini property-testing framework (offline substitute for proptest).
//!
//! A property is a closure over a [`crate::util::Rng`]-driven generator;
//! the runner executes N random cases and, on failure, re-runs with a
//! bisected "shrink seed" report so failures are reproducible:
//!
//! ```no_run
//! # // no_run: doctest binaries don't inherit the xla rpath link flags
//! use umup::util::prop::{check, Config};
//! check("abs is non-negative", Config::default(), |g| {
//!     let x = g.rng.range(-1e6, 1e6);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```

use super::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xC0FFEE }
    }
}

/// Per-case generator handle.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    /// A float magnitude spread log-uniformly across many octaves —
    /// the right distribution for numeric-format edge hunting.
    pub fn wide_f32(&mut self) -> f32 {
        let sign = if self.rng.f64() < 0.5 { -1.0 } else { 1.0 };
        let log2 = self.rng.range(-40.0, 40.0);
        (sign * 2f64.powf(log2)) as f32
    }

    /// Vector of wide floats.
    pub fn wide_vec(&mut self, max_len: usize) -> Vec<f32> {
        let n = 1 + self.rng.below(max_len);
        (0..n).map(|_| self.wide_f32()).collect()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }
}

/// Run `prop` for `cfg.cases` random cases; panics (with the case number
/// and derived seed) on the first failure.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cfg: Config, prop: F) {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Rng::new(seed), case };
            prop(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially() {
        check("tautology", Config { cases: 32, ..Default::default() }, |g| {
            let v = g.wide_vec(16);
            assert!(!v.is_empty());
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn reports_failures() {
        check("always fails", Config { cases: 4, ..Default::default() }, |_| {
            panic!("boom");
        });
    }

    #[test]
    fn wide_f32_covers_octaves() {
        let mut g = Gen { rng: Rng::new(1), case: 0 };
        let mut small = false;
        let mut large = false;
        for _ in 0..1000 {
            let x = g.wide_f32().abs();
            small |= x < 1e-6;
            large |= x > 1e6;
        }
        assert!(small && large);
    }
}
