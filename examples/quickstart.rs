//! Quickstart: train a u-μP proxy model for a few hundred steps and show
//! LR-sweep-free training at unit-scale defaults.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::path::Path;
use std::sync::Arc;

use umup::data::{Corpus, CorpusConfig};
use umup::engine::{Engine, EngineConfig, EngineJob};
use umup::parametrization::{HpSet, Parametrization, Scheme};
use umup::runtime::Registry;
use umup::train::{RunConfig, Schedule};

fn main() -> anyhow::Result<()> {
    // 1. open the AOT artifact registry (built by `make artifacts`)
    let registry = Registry::open(Path::new("artifacts"))?;
    let manifest = registry.find(64, 4, 16)?;
    println!("model: {} ({} params)", manifest.name, manifest.n_params);

    // 2. synthetic corpus (WikiText-103 stand-in, DESIGN.md §4)
    let corpus = Arc::new(Corpus::generate(CorpusConfig {
        vocab: manifest.spec.vocab,
        ..Default::default()
    }));
    println!(
        "corpus: H1={:.2} nats, H2={:.2} nats, {} train tokens",
        corpus.unigram_entropy(),
        corpus.bigram_entropy(),
        corpus.train_slice().len()
    );

    // 3. a u-μP run through the engine: every HP at its default of 1
    //    except the LR — the paper's point is that this is already
    //    near-optimal (§4.5)
    let steps = 300;
    let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() })?;
    let mut cfg = RunConfig::quick(
        "quickstart-umup",
        Parametrization::new(Scheme::Umup),
        HpSet::with_eta(0.5),
        steps,
    );
    cfg.schedule = Schedule::standard(0.5, steps, 75);
    // submit_one returns a handle immediately; result() blocks for the
    // strict outcome (a sweep would instead stream per-job outcomes)
    let handle = engine.submit_one(EngineJob::new(
        Arc::clone(&manifest),
        Arc::clone(&corpus),
        cfg,
        vec![],
    ));
    let record = handle.result()?.record;

    for &(step, loss) in &record.train_curve {
        println!("step {step:5}  train loss {loss:.4}");
    }
    println!(
        "\nfinal validation loss {:.4} (bigram entropy floor ≈ {:.4})",
        record.final_valid_loss,
        corpus.bigram_entropy()
    );
    println!("wall time {:.1}s", record.wall_seconds);
    Ok(())
}
