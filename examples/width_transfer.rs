//! μTransfer demo (paper Fig 1b, miniature): sweep the LR on a small
//! proxy, transfer the optimum to a 4x wider target, and show it lands
//! near the target's own optimum for u-μP.
//!
//! One engine serves all four sweeps: both the proxy and target sweeps
//! are *submitted* up front (non-blocking handles) so the affinity
//! scheduler interleaves them across workers without thrashing session
//! pools, its per-worker pools keep the w64 and w256 compiles alive
//! across schemes, and its run cache deduplicates any repeated
//! (manifest, config) pair.
//!
//!     cargo run --release --example width_transfer

use std::path::Path;
use std::sync::Arc;

use umup::data::{Corpus, CorpusConfig};
use umup::engine::{Engine, EngineConfig, EngineJob, SweepHandle};
use umup::parametrization::{HpSet, Parametrization, Scheme};
use umup::runtime::Registry;
use umup::train::{RunConfig, Schedule};
use umup::util::stats;

/// Queue one width's LR sweep without blocking; the returned handle
/// streams outcomes while the sibling sweeps share the same workers.
fn submit_lr_sweep(
    engine: &Engine,
    registry: &Registry,
    width: usize,
    scheme: Scheme,
    grid: &[f64],
    steps: u64,
    corpus: &Arc<Corpus>,
) -> anyhow::Result<SweepHandle> {
    let man = registry.find(width, 4, 16)?;
    let jobs: Vec<EngineJob> = grid
        .iter()
        .map(|&eta| {
            let mut p = Parametrization::new(scheme);
            p.base_width = 64; // proxy shape
            let mut cfg = RunConfig::quick(
                &format!("{}-w{width}-lr{eta}", scheme.name()),
                p,
                HpSet::with_eta(eta),
                steps,
            );
            cfg.schedule = Schedule::standard(eta, steps, (steps / 4).max(1));
            EngineJob::new(Arc::clone(&man), Arc::clone(corpus), cfg, vec![("eta".into(), eta)])
        })
        .collect();
    Ok(engine.submit(jobs))
}

/// Drain a sweep handle into an (eta, loss) line, printing fresh runs
/// as they complete.
fn drain_line(handle: SweepHandle) -> anyhow::Result<Vec<(f64, f64)>> {
    let res = handle.drain_strict(|o, done, total| {
        if let (Ok(rec), false) = (&o.outcome, o.cached) {
            println!("    [{done}/{total}] {}: loss {:.4}", o.job.config.label, rec.objective());
        }
    })?;
    Ok(res.iter().map(|r| (r.job.tag[0].1, r.record.objective())).collect())
}

fn main() -> anyhow::Result<()> {
    let registry = Registry::open(Path::new("artifacts"))?;
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    let engine = Engine::new(EngineConfig { workers: 4, ..EngineConfig::default() })?;
    let steps = 200;
    for scheme in [Scheme::Mup, Scheme::Umup] {
        let grid: Vec<f64> = match scheme {
            Scheme::Umup => (-4..=2).map(|e| 2f64.powi(e)).collect(),
            _ => (-11..=-5).map(|e| 2f64.powi(e)).collect(),
        };
        println!("\n=== {} ===", scheme.name());
        // both widths queued before either is drained: one shared pool,
        // manifest affinity keeps each worker on one shape's sessions
        let proxy_handle = submit_lr_sweep(&engine, &registry, 64, scheme, &grid, steps, &corpus)?;
        let target_handle =
            submit_lr_sweep(&engine, &registry, 256, scheme, &grid, steps, &corpus)?;
        let proxy = drain_line(proxy_handle)?;
        let target = drain_line(target_handle)?;
        let p_best = proxy[stats::argmin(&proxy.iter().map(|p| p.1).collect::<Vec<_>>())];
        let t_best = target[stats::argmin(&target.iter().map(|p| p.1).collect::<Vec<_>>())];
        // loss at the *transferred* LR on the target
        let transferred = target
            .iter()
            .find(|(lr, _)| (lr / p_best.0 - 1.0).abs() < 1e-9)
            .copied()
            .unwrap_or(t_best);
        println!("proxy  (w64)  optimum: lr=2^{:+.1} loss={:.4}", p_best.0.log2(), p_best.1);
        println!("target (w256) optimum: lr=2^{:+.1} loss={:.4}", t_best.0.log2(), t_best.1);
        println!(
            "transferred proxy LR -> target loss {:.4} (excess {:+.4}, drift {:.1} octaves)",
            transferred.1,
            transferred.1 - t_best.1,
            (p_best.0 / t_best.0).log2().abs()
        );
    }
    let s = engine.stats();
    println!(
        "\nengine: {} runs executed, {} cache hits, {} deduped \
         (session affinity: {} hits / {} steals)",
        s.executed, s.cache_hits, s.deduped, s.pool_hits, s.pool_steals
    );
    println!("Expected shape: u-muP drift ≈ 0 octaves with ~no excess loss; muP drifts.");
    Ok(())
}
