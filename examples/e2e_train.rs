//! END-TO-END DRIVER (DESIGN.md §5 "e2e"): exercises every layer of the
//! stack on a real workload — L1 Pallas quantizer inside the compiled
//! step, L2 fused fwd/bwd/AdamW graph, L3 parametrization engine, PJRT
//! runtime, corpus, schedule, telemetry — by training the largest
//! compiled model (width 256, ~3.5M params) for several hundred steps in
//! both precisions and logging the loss curves, RMS telemetry, probe
//! perplexities and runtime throughput.
//!
//! The trained state feeds downstream probe evals, so this uses the
//! engine's caller-thread session pool (`Engine::runner`) rather than
//! the job queue.
//!
//!     cargo run --release --example e2e_train [-- steps]

use std::path::Path;
use std::sync::Arc;

use umup::data::{probe_suite, Corpus, CorpusConfig};
use umup::engine::{Engine, EngineConfig};
use umup::parametrization::{HpSet, Parametrization, Precision, Scheme};
use umup::runtime::Registry;
use umup::train::{RunConfig, Schedule};

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(400);
    let registry = Registry::open(Path::new("artifacts"))?;
    let manifest = registry.find(256, 4, 16)?;
    println!(
        "e2e: {} — {} params, batch {} x seq {} ({} tokens/step), {steps} steps",
        manifest.name,
        manifest.n_params,
        manifest.spec.batch,
        manifest.spec.seq,
        manifest.spec.batch * manifest.spec.seq
    );
    let corpus = Arc::new(Corpus::generate(CorpusConfig {
        vocab: manifest.spec.vocab,
        ..Default::default()
    }));
    println!(
        "corpus: {} tokens, H1={:.3} H2={:.3} nats",
        corpus.tokens.len(),
        corpus.unigram_entropy(),
        corpus.bigram_entropy()
    );
    let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() })?;
    // one compile, shared across both precision runs via the engine pool
    let runner = engine.runner(&manifest)?;

    for precision in [Precision::Fp32, Precision::Fp8Paper] {
        println!("\n--- u-muP {} ---", precision.name());
        let mut cfg = RunConfig::quick(
            &format!("e2e-{}", precision.name()),
            Parametrization::new(Scheme::Umup),
            HpSet::with_eta(0.5),
            steps,
        );
        cfg.precision = precision;
        cfg.schedule = Schedule::standard(0.5, steps, steps / 4);
        cfg.log_every = (steps / 20).max(1);
        cfg.rms_sites = vec![
            "w.head".into(),
            "act.l3.down_in".into(),
            "act.l3.qkv_in".into(),
        ];
        let (rec, ts) = runner.run_full(&cfg, &corpus)?;
        for &(t, l) in &rec.train_curve {
            println!("  step {t:5}  loss {l:.4}");
        }
        let tok_per_s =
            steps as f64 * (manifest.spec.batch * manifest.spec.seq) as f64 / rec.wall_seconds;
        println!(
            "  final valid loss {:.4}  | {:.1}s  | {:.0} tokens/s",
            rec.final_valid_loss, rec.wall_seconds, tok_per_s
        );
        for (site, curve) in &rec.rms_curves {
            println!(
                "  RMS {site}: {:.3} -> {:.3}",
                curve.first().unwrap().1,
                curve.last().unwrap().1
            );
        }
        // downstream probes (Table 4 substitute)
        for (name, pc) in probe_suite(&corpus.config, 60_000) {
            let loss = runner.eval_on(&ts, &pc, 4)?;
            println!("  probe {name:14} perplexity {:.3}", loss.exp());
        }
    }
    println!("\ne2e complete: all layers composed (see EXPERIMENTS.md for the recorded run)");
    Ok(())
}
