//! Out-of-the-box FP8 training (paper Fig 1c / §4.2): the same u-μP
//! model trained in full precision, with the naive all-matmul
//! `.to(float8)` cast, and with the paper's mixed-precision scheme
//! (critical tensors kept high) — plus an SP model under the naive cast
//! to show why unit scale matters.
//!
//! All five runs are queued as one engine batch, so the example also
//! demonstrates the engine's per-job outcome reporting.
//!
//!     cargo run --release --example fp8_training

use std::path::Path;
use std::sync::Arc;

use umup::data::{Corpus, CorpusConfig};
use umup::engine::{Engine, EngineConfig, EngineJob};
use umup::parametrization::{HpSet, Parametrization, Precision, Scheme};
use umup::runtime::Registry;
use umup::train::{RunConfig, Schedule};

fn main() -> anyhow::Result<()> {
    let registry = Registry::open(Path::new("artifacts"))?;
    let manifest = registry.find(64, 4, 16)?;
    let corpus = Arc::new(Corpus::generate(CorpusConfig {
        vocab: manifest.spec.vocab,
        ..Default::default()
    }));
    let engine = Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() })?;
    let steps = 300;

    let cases = [
        ("u-muP fp32", Scheme::Umup, Precision::Fp32, 0.5),
        ("u-muP fp8 naive-cast", Scheme::Umup, Precision::Fp8Naive, 0.5),
        ("u-muP fp8 paper-scheme", Scheme::Umup, Precision::Fp8Paper, 0.5),
        ("SP    fp32", Scheme::Sp, Precision::Fp32, 2f64.powi(-8)),
        ("SP    fp8 naive-cast", Scheme::Sp, Precision::Fp8Naive, 2f64.powi(-8)),
    ];
    let jobs: Vec<EngineJob> = cases
        .iter()
        .map(|&(label, scheme, precision, eta)| {
            let mut cfg =
                RunConfig::quick(label, Parametrization::new(scheme), HpSet::with_eta(eta), steps);
            cfg.precision = precision;
            cfg.schedule = Schedule::standard(eta, steps, 75);
            EngineJob::new(Arc::clone(&manifest), Arc::clone(&corpus), cfg, vec![])
        })
        .collect();

    // non-blocking submission: outcomes stream back in *completion*
    // order, so each run prints the moment it finishes instead of
    // waiting for the slowest of the five
    let mut handle = engine.submit(jobs);
    let mut results: Vec<Option<f64>> = vec![None; cases.len()];
    while let Some(out) = handle.recv() {
        let label = cases[out.idx].0;
        match &out.outcome {
            Ok(rec) => {
                println!(
                    "[{}/{}] {label:24} final valid loss {:.4}  diverged={}  [{:.1}s]",
                    handle.emitted(),
                    cases.len(),
                    rec.final_valid_loss,
                    rec.diverged,
                    rec.wall_seconds
                );
                results[out.idx] = Some(rec.final_valid_loss);
            }
            Err(e) => println!("{label:24} FAILED: {e}"),
        }
    }
    let s = engine.stats();
    println!(
        "engine: {} run, {} cached, {} deduped, {} failed",
        s.executed, s.cache_hits, s.deduped, s.failed
    );
    let results: Vec<f64> = results.into_iter().flatten().collect();
    if results.len() == cases.len() {
        let umup_degradation = results[1] - results[0];
        let sp_degradation = results[4] - results[3];
        println!("\nFP8 degradation: u-muP {umup_degradation:+.4} vs SP {sp_degradation:+.4}");
        println!("Paper claim: the u-muP gap is minimal; the SP gap is larger (its tensors");
        println!("sit far from unit RMS, so the naive cast clips/underflows them).");
    }
    Ok(())
}
