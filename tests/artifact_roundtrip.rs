//! Integration: every artifact loads, compiles and executes; the L1
//! Pallas quantizer kernel artifact agrees bit-exactly with the Rust
//! software codec (the cross-layer numeric contract).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use umup::engine::{Engine, EngineConfig};
use umup::formats::{BF16, E4M3, E5M2, FP16};
use umup::parametrization::{HpSet, Parametrization, Precision, RuntimeVectors, Scheme};
use umup::runtime::{Manifest, Registry};
use umup::util::Rng;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Compiled artifacts come from the Python AOT pipeline (`make
/// artifacts`) and are not checked in; on runners without them these
/// tests skip rather than fail.
macro_rules! require_artifacts {
    () => {
        if !artifacts().is_dir() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn manifests_validate() {
    require_artifacts!();
    let reg = Registry::open(&artifacts()).unwrap();
    assert!(reg.manifests().len() >= 10, "expected the full spec matrix");
    for man in reg.manifests() {
        man.validate().unwrap();
        // every quant site's matmul has a scale site
        for site in man.quant_sites.keys() {
            let base = site.rsplit_once('.').unwrap().0;
            assert!(
                man.scale_sites.contains_key(&format!("{base}.out")),
                "quant site {site} lacks scale site"
            );
        }
    }
}

#[test]
fn every_artifact_steps() {
    require_artifacts!();
    let reg = Registry::open(&artifacts()).unwrap();
    let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() }).unwrap();
    for man in reg.manifests() {
        // compile+run a representative subset to keep CI fast (tiny,
        // standard proxy, deep, trainable-norms); the rest are covered
        // by `repro check` and the experiment runs
        let keep = ["w32_d2_b4_t16_v64", "w64_d4_b16_t64_v256", "w64_d8_b16_t64_v256",
                    "w32_d4_b16_t64_v256_tn"];
        if !keep.contains(&man.name.as_str()) {
            continue;
        }
        let session = engine.session(man).unwrap();
        let vecs = RuntimeVectors::build(
            man,
            &Parametrization::new(Scheme::Umup),
            &HpSet::with_eta(0.5),
            Precision::Fp32,
        )
        .unwrap();
        let mut ts = session
            .init(1, &vecs.init_std, &vecs.scales, &vecs.lr_scale, &vecs.qmask)
            .unwrap();
        let mut rng = Rng::new(5);
        let tokens: Vec<i32> = (0..man.spec.batch * (man.spec.seq + 1))
            .map(|_| rng.below(man.spec.vocab) as i32)
            .collect();
        let hyp = umup::train::AdamConfig::default().hyp(0.25, 1);
        let l1 = session.step(&mut ts, &tokens, &hyp).unwrap();
        let hyp2 = umup::train::AdamConfig::default().hyp(0.25, 2);
        let l2 = session.step(&mut ts, &tokens, &hyp2).unwrap();
        assert!(l1.is_finite() && l2.is_finite(), "{}", man.name);
        assert!(l2 < l1, "{}: same-batch loss must drop ({l1} -> {l2})", man.name);
    }
}

/// The standalone Pallas quantizer artifacts vs the Rust codec:
/// bit-exact agreement across 128x128 wide-range inputs, all 4 formats.
#[test]
fn pallas_quantizer_matches_rust_codec() {
    require_artifacts!();
    let dir = artifacts().join("kernels");
    let client = xla::PjRtClient::cpu().unwrap();
    for (name, fmt) in [
        ("e4m3", E4M3),
        ("e5m2", E5M2),
        ("bf16", BF16),
        ("fp16", FP16),
    ] {
        let path = dir.join(format!("quantize_{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path).unwrap();
        let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();
        let mut rng = Rng::new(42);
        let xs: Vec<f32> = (0..128 * 128)
            .map(|_| {
                let sign = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
                (sign * 2f64.powf(rng.range(-30.0, 30.0))) as f32
            })
            .collect();
        let lit = xla::Literal::vec1(&xs).reshape(&[128, 128]).unwrap();
        let out = exe.execute::<xla::Literal>(&[lit]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        let kernel_out: Vec<f32> = out.to_vec().unwrap();
        let mut expect = xs.clone();
        fmt.quantize_slice(&mut expect);
        let n_bad = kernel_out
            .iter()
            .zip(&expect)
            .filter(|(a, b)| a.to_bits() != b.to_bits())
            .count();
        assert_eq!(n_bad, 0, "{name}: {n_bad} mismatches vs Rust codec");
    }
}

/// The tiled u_matmul kernel artifact computes (x @ w)/sqrt(128).
#[test]
fn pallas_matmul_artifact() {
    require_artifacts!();
    let path = artifacts().join("kernels/u_matmul_128.hlo.txt");
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file(&path).unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();
    let mut rng = Rng::new(9);
    let a: Vec<f32> = (0..128 * 128).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..128 * 128).map(|_| rng.normal() as f32).collect();
    let la = xla::Literal::vec1(&a).reshape(&[128, 128]).unwrap();
    let lb = xla::Literal::vec1(&b).reshape(&[128, 128]).unwrap();
    let out = exe.execute::<xla::Literal>(&[la, lb]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let got: Vec<f32> = out.to_vec().unwrap();
    // reference matmul
    let scale = 1.0 / (128f64).sqrt();
    let mut max_err = 0f64;
    for i in 0..128 {
        for j in 0..128 {
            let mut acc = 0f64;
            for k in 0..128 {
                acc += a[i * 128 + k] as f64 * b[k * 128 + j] as f64;
            }
            let want = acc * scale;
            max_err = max_err.max((got[i * 128 + j] as f64 - want).abs());
        }
    }
    assert!(max_err < 1e-3, "max err {max_err}");
    // unit scaling: unit inputs -> ~unit output RMS
    let rms =
        (got.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>() / got.len() as f64).sqrt();
    assert!((rms - 1.0).abs() < 0.1, "rms {rms}");
}

/// Deterministic init: same seed → identical state, different seed → not.
#[test]
fn init_determinism() {
    require_artifacts!();
    let dir = artifacts().join("w32_d2_b4_t16_v64");
    let man = Arc::new(Manifest::load(&dir).unwrap());
    let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() }).unwrap();
    let session = engine.session(&man).unwrap();
    let vecs = RuntimeVectors::build(
        &man,
        &Parametrization::new(Scheme::Umup),
        &HpSet::with_eta(0.5),
        Precision::Fp32,
    )
    .unwrap();
    let a = session
        .init(3, &vecs.init_std, &vecs.scales, &vecs.lr_scale, &vecs.qmask)
        .unwrap();
    let b = session
        .init(3, &vecs.init_std, &vecs.scales, &vecs.lr_scale, &vecs.qmask)
        .unwrap();
    let c = session
        .init(4, &vecs.init_std, &vecs.scales, &vecs.lr_scale, &vecs.qmask)
        .unwrap();
    let va = session.download_state(&a).unwrap();
    let vb = session.download_state(&b).unwrap();
    let vc = session.download_state(&c).unwrap();
    assert_eq!(va, vb);
    assert_ne!(va, vc);
    // u-μP init: unit weight RMS
    let n = man.n_params;
    let rms = (va[..n].iter().map(|x| (*x as f64).powi(2)).sum::<f64>() / n as f64).sqrt();
    assert!((rms - 1.0).abs() < 0.02, "unit init rms {rms}");
}
