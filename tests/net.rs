//! Integration suite for the network execution layer: the
//! `NetworkBackend` + `repro worker --listen` data plane and the
//! `repro serve` / `repro ctl` control plane.
//!
//! No XLA needed: the fleet is `repro worker --mock --listen` (the
//! repro binary itself, located via `CARGO_BIN_EXE_repro`), whose
//! executor is the same canonical deterministic mock
//! (`umup::engine::det_record`) the in-process `MockBackend` uses — so
//! the byte-identity assertion is a real statement about the wire/cache
//! codec over TCP, not luck.  `UMUP_CACHE_TS` is pinned in this process
//! (the engine side writes all cache lines); failure injection and
//! per-job latency in the workers are armed through the
//! `UMUP_MOCK_FAIL` / `UMUP_MOCK_FAIL_ONCE` / `UMUP_MOCK_SLEEP_MS` env
//! knobs documented in `main.rs`.

mod common;

use std::collections::BTreeSet;
use std::io::{BufRead, BufReader, Cursor};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::AtomicUsize;
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{det_mock_engine, key_of_line, shared_job_list, sorted_segment_lines};
use umup::engine::backend::wire;
use umup::engine::{Engine, EngineConfig, NetworkBackend};
use umup::util::{Json, Rng};

fn repro_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_repro"))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("umup-net-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Pin the cache timestamp so segment lines are byte-reproducible.
/// Process-wide, but every test in this binary pins the same value, so
/// parallel test threads cannot disagree.
fn pin_cache_ts() {
    std::env::set_var("UMUP_CACHE_TS", "1700000000");
}

/// Spawn one `repro worker --mock --listen 127.0.0.1:0` and read its
/// `listening <addr>` announcement back; the ephemeral port makes the
/// fleet collision-free across parallel test runs.
fn spawn_listen_worker(envs: &[(&str, &str)]) -> (Child, String) {
    let mut cmd = Command::new(repro_exe());
    cmd.arg("worker").arg("--mock").arg("--listen").arg("127.0.0.1:0");
    cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::null());
    for (k, v) in envs {
        cmd.env(k, v);
    }
    let mut child = cmd.spawn().expect("spawning listen worker");
    let stdout = child.stdout.take().expect("worker stdout is piped");
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).expect("reading the listen announcement");
    let addr = line
        .strip_prefix("listening ")
        .unwrap_or_else(|| panic!("unexpected worker announcement {line:?}"))
        .trim()
        .to_string();
    (child, addr)
}

fn kill_fleet(fleet: Vec<Child>) {
    for mut child in fleet {
        let _ = child.kill();
        let _ = child.wait();
    }
}

// ---------------------------------------------------- wire adversaries

/// The frame reader against adversarial streams: torn frames at every
/// byte offset, garbage prefixes, newline-free streams that press the
/// bounded (64-byte) prefix read, and oversized lengths.  Every case
/// must return promptly with an error (or clean EOF exactly at a frame
/// boundary) — never a bogus frame, never a hang, never a panic.
#[test]
fn read_frame_rejects_adversarial_streams_without_hanging() {
    // a valid frame cut at every byte offset: only the zero-byte cut is
    // a clean EOF; every partial cut is an error
    let mut full = Vec::new();
    wire::write_frame(&mut full, "{\"key\":\"00aabbccddeeff11\",\"payload\":\"xyz\"}").unwrap();
    for cut in 0..full.len() {
        let mut r = Cursor::new(full[..cut].to_vec());
        match wire::read_frame(&mut r) {
            Ok(None) => assert_eq!(cut, 0, "clean EOF is only legal at a frame boundary"),
            Ok(Some(p)) => panic!("stream torn at byte {cut} decoded as a frame {p:?}"),
            Err(_) => assert!(cut > 0, "the empty stream must be a clean EOF, not an error"),
        }
    }
    // ... and the untorn stream is one frame then a clean EOF
    let mut r = Cursor::new(full);
    assert!(wire::read_frame(&mut r).unwrap().is_some());
    assert!(wire::read_frame(&mut r).unwrap().is_none());

    // deterministic garbage: a non-digit lead byte followed by random
    // bytes (possibly invalid UTF-8) must fail the prefix parse — the
    // reader may not skip, resync, or buffer unboundedly
    let leads = b"{}*#!xzq";
    let mut rng = Rng::new(2024);
    for case in 0..200 {
        let n = 1 + (rng.f64() * 96.0) as usize;
        let mut bytes: Vec<u8> = (0..n).map(|_| (rng.f64() * 256.0) as u8).collect();
        bytes[0] = leads[(rng.f64() * leads.len() as f64) as usize % leads.len()];
        let mut r = Cursor::new(bytes);
        assert!(wire::read_frame(&mut r).is_err(), "garbage case {case} did not error");
    }

    // a newline-free digit stream: the bounded prefix read must give up
    // at 64 bytes (64 ones overflow usize) instead of buffering forever
    let mut r = Cursor::new(vec![b'1'; 100]);
    assert!(wire::read_frame(&mut r).is_err(), "newline-free digits must fail the prefix read");
    // 64 zeros *do* parse (length 0), so framing must fail instead:
    // the 65th byte is not the newline terminator a 0-length frame needs
    let mut r = Cursor::new(vec![b'0'; 100]);
    assert!(wire::read_frame(&mut r).is_err(), "a zero-run must fail the terminator check");

    // a syntactically valid length over the frame cap is rejected
    // before any payload allocation
    let mut r = Cursor::new(format!("{}\nx", 65 << 20).into_bytes());
    let err = wire::read_frame(&mut r).unwrap_err();
    assert!(format!("{err:#}").contains("cap"), "oversized length must name the cap: {err:#}");
}

// ------------------------------------------------------- data plane

/// One 4-worker engine drain of the shared sweep against `addrs` at
/// the given pipeline depth; returns the backend (for restart
/// accounting) and the engine report.
fn net_drain(
    addrs: &[String],
    depth: usize,
    dir: &std::path::Path,
) -> (Arc<NetworkBackend>, umup::engine::EngineReport) {
    let backend = Arc::new(
        NetworkBackend::new(&addrs.join(","))
            .unwrap()
            .with_max_restarts(2)
            .with_pipeline_depth(depth),
    );
    let engine = Engine::with_backend(
        EngineConfig {
            workers: 4,
            cache_dir: Some(dir.to_path_buf()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::clone(&backend) as Arc<dyn umup::engine::Backend>,
    )
    .unwrap();
    let report = engine.run(shared_job_list());
    drop(engine);
    (backend, report)
}

/// The acceptance test: 4-endpoint `NetworkBackend` drains of the
/// shared sweep over loopback TCP at `--pipeline-depth 1` (lockstep)
/// and `--pipeline-depth 4` (windowed, with one worker process killed
/// mid-window) produce run caches byte-identical to each other and to
/// the in-process run — the killed worker's whole unacknowledged
/// window is re-dispatched (exactly once each: the cache holds exactly
/// one line per job), `failed == 0`, and the reconnect is accounted.
#[test]
fn network_drain_with_worker_kill_is_byte_identical_to_in_process() {
    pin_cache_ts();
    let in_dir = tmp_dir("inproc");
    let d1_dir = tmp_dir("drain-d1");
    let d4_dir = tmp_dir("drain-d4");
    let marker = tmp_dir("kill-marker").with_extension("once");
    let _ = std::fs::remove_file(&marker);
    let n_jobs = shared_job_list().len();

    // reference: in-process deterministic mock
    let counter = Arc::new(AtomicUsize::new(0));
    let engine = det_mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(in_dir.clone()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::clone(&counter),
    );
    let report = engine.run(shared_job_list());
    assert_eq!(report.completed, n_jobs);
    drop(engine);

    // depth 1: strict lockstep over a healthy 4-listener fleet
    let mut fleet1 = Vec::new();
    let mut addrs1 = Vec::new();
    for _ in 0..4 {
        let (child, addr) = spawn_listen_worker(&[]);
        fleet1.push(child);
        addrs1.push(addr);
    }
    let (backend, report) = net_drain(&addrs1, 1, &d1_dir);
    assert_eq!(report.completed, n_jobs);
    assert_eq!(report.failed, 0);
    assert_eq!(backend.restarts(), 0, "a healthy lockstep drain must not reconnect");
    kill_fleet(fleet1);

    // depth 4: windowed dispatch, every listener armed to die before
    // its first reply, with a shared marker so exactly one actually
    // does — taking its whole in-flight window down with it
    let marker_s = marker.to_str().unwrap().to_string();
    let mut fleet = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..4 {
        let (child, addr) = spawn_listen_worker(&[
            ("UMUP_MOCK_FAIL", "crash-before-reply"),
            ("UMUP_MOCK_FAIL_ONCE", &marker_s),
        ]);
        fleet.push(child);
        addrs.push(addr);
    }
    let (backend, report) = net_drain(&addrs, 4, &d4_dir);
    assert_eq!(
        report.completed, n_jobs,
        "every job in the killed worker's window must be re-dispatched"
    );
    assert_eq!(report.failed, 0);
    assert_eq!(report.executed, n_jobs);

    assert!(marker.exists(), "the worker-kill injection never fired");
    assert!(backend.restarts() >= 1, "the lost connection must be accounted as a reconnect");

    let reference = sorted_segment_lines(&in_dir);
    let lockstep = sorted_segment_lines(&d1_dir);
    let windowed = sorted_segment_lines(&d4_dir);
    // exactly one cache line per job: a window job re-dispatched more
    // than once (or double-reported) would show up as a duplicate
    assert_eq!(reference.len(), n_jobs);
    assert_eq!(
        lockstep, reference,
        "depth-1 network cache must be byte-identical to the in-process one"
    );
    assert_eq!(
        windowed, reference,
        "depth-4 network cache must be byte-identical to the in-process one"
    );

    kill_fleet(fleet);
    let _ = std::fs::remove_file(&marker);
    let _ = std::fs::remove_dir_all(&in_dir);
    let _ = std::fs::remove_dir_all(&d1_dir);
    let _ = std::fs::remove_dir_all(&d4_dir);
}

// ------------------------------------------- windowed reply adversaries
//
// These drive a `NetworkBackend` executor directly (via
// `Backend::spawn_executor` + `Executor::run_batch`) against a
// hand-rolled listener speaking raw `wire::` frames, so each test
// controls exactly how the "worker" misbehaves inside a reply window.
// The contract under test: every job gets exactly one `done` call —
// a correct completion or a per-job `Err` — never a hang, and a reply
// keyed outside the window can never be filed as some job's record.

/// Bind a loopback listener whose connections are served sequentially
/// by `handler(conn_index, reader, writer)`; the hello frame is sent
/// before the handler runs.  Returns the dialable address.  The
/// accept thread is deliberately detached: it blocks in `accept`
/// until the test process exits.
fn adversarial_listener(
    handler: impl Fn(
            usize,
            &mut BufReader<std::net::TcpStream>,
            &mut std::net::TcpStream,
        ) -> anyhow::Result<()>
        + Send
        + 'static,
) -> String {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for (i, stream) in listener.incoming().enumerate() {
            let Ok(stream) = stream else { break };
            let mut w = stream.try_clone().unwrap();
            let mut r = BufReader::new(stream);
            if wire::write_frame(&mut w, &wire::hello_line()).is_err() {
                continue;
            }
            let _ = handler(i, &mut r, &mut w);
        }
    });
    addr
}

/// Read one job frame off the stream (panicking on EOF/garbage — the
/// engine side is the honest peer in these tests).
fn read_job(r: &mut BufReader<std::net::TcpStream>) -> wire::WireJob {
    let line = wire::read_frame(r).unwrap().expect("engine hung up mid-window");
    wire::decode_job(&line).unwrap()
}

/// The canonical correct reply for a job frame: the deterministic mock
/// record, encoded as the cache line (same bytes `repro worker --mock`
/// would send).
fn ok_reply_for(wj: &wire::WireJob) -> String {
    wire::ok_reply_line(&wj.key, &wj.manifest, &umup::engine::det_record(&wj.config))
}

/// Drain the engine's remaining frames until it hangs up.  Misbehaving
/// handlers end with this instead of closing early, so the socket
/// never resets with unread data in flight (a reset could race the
/// replies already sent and make the engine's view nondeterministic).
fn drain_to_eof(r: &mut BufReader<std::net::TcpStream>) {
    while let Ok(Some(_)) = wire::read_frame(r) {}
}

/// A 4-job window plus its per-job completion log: run `run_batch`
/// over the first 4 shared jobs and record each `done` outcome,
/// asserting the exactly-once contract as it streams.
fn run_window_against(addr: &str, max_restarts: usize) -> Vec<anyhow::Result<umup::train::RunRecord>> {
    use umup::engine::{Backend as _, Executor as _};
    let backend = NetworkBackend::new(addr)
        .unwrap()
        .with_max_restarts(max_restarts)
        .with_pipeline_depth(4);
    let mut exec = backend.spawn_executor(0);
    let jobs: Vec<_> = shared_job_list().into_iter().take(4).collect();
    let keys: Vec<String> = jobs.iter().map(|j| j.key()).collect();
    let refs: Vec<(&umup::engine::EngineJob, &str)> =
        jobs.iter().zip(keys.iter()).map(|(j, k)| (j, k.as_str())).collect();
    let mut results: Vec<Option<anyhow::Result<umup::train::RunRecord>>> =
        (0..refs.len()).map(|_| None).collect();
    exec.run_batch(&refs, &mut |i, r| {
        assert!(results[i].is_none(), "job {i} reported twice");
        results[i] = Some(r);
    });
    results
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.unwrap_or_else(|| panic!("job {i} never reported")))
        .collect()
}

/// Expected cache-line bytes for shared job `i` (depth-independent:
/// the reply line *is* the cache line).
fn expected_line(i: usize) -> String {
    let job = &shared_job_list()[i];
    wire::ok_reply_line(
        &job.key(),
        &job.manifest.name,
        &umup::engine::det_record(&job.config),
    )
}

/// Reply reordering within a window is legal: the worker answers the
/// whole 4-job window in reverse, and every job must still complete
/// with *its own* record (matched by key, not arrival order), with no
/// reconnect consumed.
#[test]
fn windowed_replies_out_of_order_complete_every_job_correctly() {
    pin_cache_ts();
    let addr = adversarial_listener(|_, r, w| {
        let jobs: Vec<wire::WireJob> = (0..4).map(|_| read_job(r)).collect();
        for wj in jobs.iter().rev() {
            wire::write_frame(w, &ok_reply_for(wj))?;
        }
        drain_to_eof(r);
        Ok(())
    });
    let results = run_window_against(&addr, 2);
    for (i, result) in results.iter().enumerate() {
        let rec = result.as_ref().unwrap_or_else(|e| panic!("job {i} failed: {e:#}"));
        let job = &shared_job_list()[i];
        assert_eq!(
            wire::ok_reply_line(&job.key(), &job.manifest.name, rec),
            expected_line(i),
            "job {i} completed with some other job's record"
        );
    }
}

/// A reply keyed to nothing in the window is a protocol desync: the
/// connection is torn down and the *whole* window re-dispatched once —
/// the stray record is never filed as any job's completion.
#[test]
fn windowed_unknown_key_reply_is_redispatched_never_miscached() {
    pin_cache_ts();
    let addr = adversarial_listener(|conn, r, w| {
        if conn == 0 {
            // echo a record for a key the engine never submitted
            let wj = read_job(r);
            let stray = wire::ok_reply_line(
                "00000000deadbeef",
                &wj.manifest,
                &umup::engine::det_record(&wj.config),
            );
            wire::write_frame(w, &stray)?;
            drain_to_eof(r);
        } else {
            // the re-dispatch target behaves
            while let Some(line) = wire::read_frame(r)? {
                wire::write_frame(w, &ok_reply_for(&wire::decode_job(&line)?))?;
            }
        }
        Ok(())
    });
    let results = run_window_against(&addr, 2);
    for (i, result) in results.iter().enumerate() {
        let rec = result.as_ref().unwrap_or_else(|e| panic!("job {i} failed: {e:#}"));
        let job = &shared_job_list()[i];
        assert_eq!(
            wire::ok_reply_line(&job.key(), &job.manifest.name, rec),
            expected_line(i),
            "job {i} must complete with its own record after the re-dispatch"
        );
    }
}

/// A duplicate reply for an already-acknowledged key is the same
/// desync, and a worker that desyncs on every connection exhausts the
/// one re-dispatch: the jobs acknowledged before each desync keep
/// their (single) completions, every job still unacknowledged after
/// the re-dispatch gets a per-job `Err` — and nothing hangs.
#[test]
fn windowed_duplicate_key_reply_fails_residual_jobs_after_one_redispatch() {
    pin_cache_ts();
    let addr = adversarial_listener(|_, r, w| {
        // every connection: answer the first job correctly, then
        // answer it AGAIN (its key has left the window)
        let wj = read_job(r);
        let reply = ok_reply_for(&wj);
        wire::write_frame(w, &reply)?;
        wire::write_frame(w, &reply)?;
        drain_to_eof(r);
        Ok(())
    });
    let results = run_window_against(&addr, 1);
    // window order is the jobs slice order: conn 0 acks job 0 then
    // desyncs; the re-dispatch (conn 1) acks job 1 then desyncs; with
    // the single re-dispatch spent, jobs 2 and 3 fail per-job
    for (i, result) in results.iter().enumerate().take(2) {
        let rec = result.as_ref().unwrap_or_else(|e| panic!("job {i} failed: {e:#}"));
        let job = &shared_job_list()[i];
        assert_eq!(
            wire::ok_reply_line(&job.key(), &job.manifest.name, rec),
            expected_line(i),
            "job {i} must keep its pre-desync completion"
        );
    }
    for (i, result) in results.iter().enumerate().skip(2) {
        let err = result.as_ref().expect_err("unacknowledged jobs must fail per-job");
        assert!(
            format!("{err:#}").contains("failed twice"),
            "job {i} error must name the exhausted re-dispatch: {err:#}"
        );
    }
}

// ---------------------------------------------------- control plane

/// One `repro ctl` invocation; asserts success and parses the verb's
/// JSON result off stdout.
fn ctl_json(addr: &str, verb: &str, extra: &[&str]) -> Json {
    let out = Command::new(repro_exe())
        .arg("ctl")
        .arg(verb)
        .args(extra)
        .arg("--addr")
        .arg(addr)
        .output()
        .expect("running repro ctl");
    assert!(
        out.status.success(),
        "ctl {verb} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    Json::parse(String::from_utf8_lossy(&out.stdout).trim()).expect("ctl output is JSON")
}

fn as_count(j: &Json, key: &str) -> usize {
    j.get(key).unwrap().as_usize().unwrap()
}

/// The acceptance test for the control plane: a live `repro serve`
/// daemon over a slow 2-worker fleet answers `repro ctl`
/// submit/status/cancel/cache-stats/shutdown round trips — cancel
/// unqueues pending jobs while in-flight ones complete and are cached,
/// and shutdown drains then exits the daemon cleanly.
#[test]
fn serve_and_ctl_round_trip_against_a_live_fleet() {
    pin_cache_ts();
    let cache = tmp_dir("serve-cache");
    // slow workers so `cancel` catches a mostly-unstarted sweep
    let mut fleet = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..2 {
        let (child, addr) = spawn_listen_worker(&[("UMUP_MOCK_SLEEP_MS", "400")]);
        fleet.push(child);
        addrs.push(addr);
    }
    let mut daemon = Command::new(repro_exe())
        .arg("serve")
        .arg("--addr")
        .arg("127.0.0.1:0")
        .arg("--workers")
        .arg(addrs.join(","))
        .arg("--cache-dir")
        .arg(&cache)
        .arg("--resume")
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning repro serve");
    let stdout = daemon.stdout.take().expect("serve stdout is piped");
    let mut reader = BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("reading serve stdout");
        assert_ne!(n, 0, "serve exited before announcing its endpoint");
        if let Some(a) = line.strip_prefix("serving ") {
            break a.trim().to_string();
        }
    };

    // the jobs file: worker wire-frame encoding, keys computed
    // client-side (the daemon recomputes and must agree)
    let jobs = shared_job_list();
    let n_jobs = jobs.len();
    let jobs_path = tmp_dir("serve-jobs").with_extension("jsonl");
    let mut text = String::new();
    for job in &jobs {
        text.push_str(&wire::encode_job(&job.key(), job));
        text.push('\n');
    }
    std::fs::write(&jobs_path, text).unwrap();

    let r = ctl_json(&addr, "submit", &["--jobs", jobs_path.to_str().unwrap()]);
    let sweep = as_count(&r, "sweep").to_string();
    assert_eq!(as_count(&r, "total"), n_jobs);

    // cancel while most of the sweep is still queued
    let r = ctl_json(&addr, "cancel", &["--sweep", &sweep]);
    assert!(r.get("cancelled").unwrap().as_bool().unwrap());

    // poll status until the sweep settles (in-flight jobs finish)
    let deadline = Instant::now() + Duration::from_secs(60);
    let status = loop {
        let s = ctl_json(&addr, "status", &["--sweep", &sweep]);
        if s.get("done").unwrap().as_bool().unwrap() {
            break s;
        }
        assert!(Instant::now() < deadline, "cancelled sweep never settled: {}", s.dump());
        std::thread::sleep(Duration::from_millis(100));
    };
    let executed = as_count(&status, "executed");
    let cancelled = as_count(&status, "cancelled");
    assert!(cancelled > 0, "cancel must unqueue pending jobs: {}", status.dump());
    assert_eq!(as_count(&status, "failed"), 0, "status: {}", status.dump());
    // `failed` is a subset of `executed`, so with zero failures these
    // four partition the sweep
    assert_eq!(
        executed
            + cancelled
            + as_count(&status, "cache_hits")
            + as_count(&status, "deduped")
            + as_count(&status, "skipped"),
        n_jobs,
        "every job must be accounted for: {}",
        status.dump()
    );

    // in-flight jobs were cached; cancelled ones were not
    let stats = ctl_json(&addr, "cache-stats", &[]);
    assert_eq!(as_count(&stats, "records"), executed, "stats: {}", stats.dump());

    // status without --sweep lists every live sweep
    let all = ctl_json(&addr, "status", &[]);
    assert_eq!(all.get("sweeps").unwrap().as_arr().unwrap().len(), 1);

    // shutdown: ok reply, then a clean daemon exit
    let r = ctl_json(&addr, "shutdown", &[]);
    assert!(r.get("shutdown").unwrap().as_bool().unwrap());
    let exit = daemon.wait().expect("waiting for serve");
    assert!(exit.success(), "serve must exit cleanly after shutdown");

    // the persisted cache holds exactly the executed jobs, every key a
    // submitted one
    let lines = sorted_segment_lines(&cache);
    assert_eq!(lines.len(), executed);
    let expected: BTreeSet<String> = jobs.iter().map(|j| j.key()).collect();
    for line in &lines {
        assert!(expected.contains(&key_of_line(line)), "cache line for an unsubmitted key");
    }

    kill_fleet(fleet);
    let _ = std::fs::remove_file(&jobs_path);
    let _ = std::fs::remove_dir_all(&cache);
}
