//! Integration suite for the network execution layer: the
//! `NetworkBackend` + `repro worker --listen` data plane and the
//! `repro serve` / `repro ctl` control plane.
//!
//! No XLA needed: the fleet is `repro worker --mock --listen` (the
//! repro binary itself, located via `CARGO_BIN_EXE_repro`), whose
//! executor is the same canonical deterministic mock
//! (`umup::engine::det_record`) the in-process `MockBackend` uses — so
//! the byte-identity assertion is a real statement about the wire/cache
//! codec over TCP, not luck.  `UMUP_CACHE_TS` is pinned in this process
//! (the engine side writes all cache lines); failure injection and
//! per-job latency in the workers are armed through the
//! `UMUP_MOCK_FAIL` / `UMUP_MOCK_FAIL_ONCE` / `UMUP_MOCK_SLEEP_MS` env
//! knobs documented in `main.rs`.

mod common;

use std::collections::BTreeSet;
use std::io::{BufRead, BufReader, Cursor};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::AtomicUsize;
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{det_mock_engine, key_of_line, shared_job_list, sorted_segment_lines};
use umup::engine::backend::wire;
use umup::engine::{Engine, EngineConfig, NetworkBackend};
use umup::util::{Json, Rng};

fn repro_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_repro"))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("umup-net-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Pin the cache timestamp so segment lines are byte-reproducible.
/// Process-wide, but every test in this binary pins the same value, so
/// parallel test threads cannot disagree.
fn pin_cache_ts() {
    std::env::set_var("UMUP_CACHE_TS", "1700000000");
}

/// Spawn one `repro worker --mock --listen 127.0.0.1:0` and read its
/// `listening <addr>` announcement back; the ephemeral port makes the
/// fleet collision-free across parallel test runs.
fn spawn_listen_worker(envs: &[(&str, &str)]) -> (Child, String) {
    let mut cmd = Command::new(repro_exe());
    cmd.arg("worker").arg("--mock").arg("--listen").arg("127.0.0.1:0");
    cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::null());
    for (k, v) in envs {
        cmd.env(k, v);
    }
    let mut child = cmd.spawn().expect("spawning listen worker");
    let stdout = child.stdout.take().expect("worker stdout is piped");
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).expect("reading the listen announcement");
    let addr = line
        .strip_prefix("listening ")
        .unwrap_or_else(|| panic!("unexpected worker announcement {line:?}"))
        .trim()
        .to_string();
    (child, addr)
}

fn kill_fleet(fleet: Vec<Child>) {
    for mut child in fleet {
        let _ = child.kill();
        let _ = child.wait();
    }
}

// ---------------------------------------------------- wire adversaries

/// The frame reader against adversarial streams: torn frames at every
/// byte offset, garbage prefixes, newline-free streams that press the
/// bounded (64-byte) prefix read, and oversized lengths.  Every case
/// must return promptly with an error (or clean EOF exactly at a frame
/// boundary) — never a bogus frame, never a hang, never a panic.
#[test]
fn read_frame_rejects_adversarial_streams_without_hanging() {
    // a valid frame cut at every byte offset: only the zero-byte cut is
    // a clean EOF; every partial cut is an error
    let mut full = Vec::new();
    wire::write_frame(&mut full, "{\"key\":\"00aabbccddeeff11\",\"payload\":\"xyz\"}").unwrap();
    for cut in 0..full.len() {
        let mut r = Cursor::new(full[..cut].to_vec());
        match wire::read_frame(&mut r) {
            Ok(None) => assert_eq!(cut, 0, "clean EOF is only legal at a frame boundary"),
            Ok(Some(p)) => panic!("stream torn at byte {cut} decoded as a frame {p:?}"),
            Err(_) => assert!(cut > 0, "the empty stream must be a clean EOF, not an error"),
        }
    }
    // ... and the untorn stream is one frame then a clean EOF
    let mut r = Cursor::new(full);
    assert!(wire::read_frame(&mut r).unwrap().is_some());
    assert!(wire::read_frame(&mut r).unwrap().is_none());

    // deterministic garbage: a non-digit lead byte followed by random
    // bytes (possibly invalid UTF-8) must fail the prefix parse — the
    // reader may not skip, resync, or buffer unboundedly
    let leads = b"{}*#!xzq";
    let mut rng = Rng::new(2024);
    for case in 0..200 {
        let n = 1 + (rng.f64() * 96.0) as usize;
        let mut bytes: Vec<u8> = (0..n).map(|_| (rng.f64() * 256.0) as u8).collect();
        bytes[0] = leads[(rng.f64() * leads.len() as f64) as usize % leads.len()];
        let mut r = Cursor::new(bytes);
        assert!(wire::read_frame(&mut r).is_err(), "garbage case {case} did not error");
    }

    // a newline-free digit stream: the bounded prefix read must give up
    // at 64 bytes (64 ones overflow usize) instead of buffering forever
    let mut r = Cursor::new(vec![b'1'; 100]);
    assert!(wire::read_frame(&mut r).is_err(), "newline-free digits must fail the prefix read");
    // 64 zeros *do* parse (length 0), so framing must fail instead:
    // the 65th byte is not the newline terminator a 0-length frame needs
    let mut r = Cursor::new(vec![b'0'; 100]);
    assert!(wire::read_frame(&mut r).is_err(), "a zero-run must fail the terminator check");

    // a syntactically valid length over the frame cap is rejected
    // before any payload allocation
    let mut r = Cursor::new(format!("{}\nx", 65 << 20).into_bytes());
    let err = wire::read_frame(&mut r).unwrap_err();
    assert!(format!("{err:#}").contains("cap"), "oversized length must name the cap: {err:#}");
}

// ------------------------------------------------------- data plane

/// The acceptance test: a 4-endpoint `NetworkBackend` drain of the
/// shared sweep over loopback TCP — with one worker process killed
/// mid-job — produces a run cache byte-identical to the in-process run,
/// with the killed job re-dispatched to a surviving endpoint (not
/// failed) and the reconnect accounted.
#[test]
fn network_drain_with_worker_kill_is_byte_identical_to_in_process() {
    pin_cache_ts();
    let in_dir = tmp_dir("inproc");
    let net_dir = tmp_dir("drain");
    let marker = tmp_dir("kill-marker").with_extension("once");
    let _ = std::fs::remove_file(&marker);
    let n_jobs = shared_job_list().len();

    // reference: in-process deterministic mock
    let counter = Arc::new(AtomicUsize::new(0));
    let engine = det_mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(in_dir.clone()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::clone(&counter),
    );
    let report = engine.run(shared_job_list());
    assert_eq!(report.completed, n_jobs);
    drop(engine);

    // the fleet: 4 listeners, every one armed to die before its first
    // reply, with a shared marker so exactly one actually does
    let marker_s = marker.to_str().unwrap().to_string();
    let mut fleet = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..4 {
        let (child, addr) = spawn_listen_worker(&[
            ("UMUP_MOCK_FAIL", "crash-before-reply"),
            ("UMUP_MOCK_FAIL_ONCE", &marker_s),
        ]);
        fleet.push(child);
        addrs.push(addr);
    }
    let backend =
        Arc::new(NetworkBackend::new(&addrs.join(",")).unwrap().with_max_restarts(2));
    let engine = Engine::with_backend(
        EngineConfig {
            workers: 4,
            cache_dir: Some(net_dir.clone()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::clone(&backend) as Arc<dyn umup::engine::Backend>,
    )
    .unwrap();
    let report = engine.run(shared_job_list());
    assert_eq!(report.completed, n_jobs, "the killed worker's job must be re-dispatched");
    assert_eq!(report.failed, 0);
    assert_eq!(report.executed, n_jobs);
    drop(engine);

    assert!(marker.exists(), "the worker-kill injection never fired");
    assert!(backend.restarts() >= 1, "the lost connection must be accounted as a reconnect");

    let reference = sorted_segment_lines(&in_dir);
    let netted = sorted_segment_lines(&net_dir);
    assert_eq!(reference.len(), n_jobs);
    assert_eq!(
        netted, reference,
        "network-backend cache must be byte-identical to the in-process one"
    );

    kill_fleet(fleet);
    let _ = std::fs::remove_file(&marker);
    let _ = std::fs::remove_dir_all(&in_dir);
    let _ = std::fs::remove_dir_all(&net_dir);
}

// ---------------------------------------------------- control plane

/// One `repro ctl` invocation; asserts success and parses the verb's
/// JSON result off stdout.
fn ctl_json(addr: &str, verb: &str, extra: &[&str]) -> Json {
    let out = Command::new(repro_exe())
        .arg("ctl")
        .arg(verb)
        .args(extra)
        .arg("--addr")
        .arg(addr)
        .output()
        .expect("running repro ctl");
    assert!(
        out.status.success(),
        "ctl {verb} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    Json::parse(String::from_utf8_lossy(&out.stdout).trim()).expect("ctl output is JSON")
}

fn as_count(j: &Json, key: &str) -> usize {
    j.get(key).unwrap().as_usize().unwrap()
}

/// The acceptance test for the control plane: a live `repro serve`
/// daemon over a slow 2-worker fleet answers `repro ctl`
/// submit/status/cancel/cache-stats/shutdown round trips — cancel
/// unqueues pending jobs while in-flight ones complete and are cached,
/// and shutdown drains then exits the daemon cleanly.
#[test]
fn serve_and_ctl_round_trip_against_a_live_fleet() {
    pin_cache_ts();
    let cache = tmp_dir("serve-cache");
    // slow workers so `cancel` catches a mostly-unstarted sweep
    let mut fleet = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..2 {
        let (child, addr) = spawn_listen_worker(&[("UMUP_MOCK_SLEEP_MS", "400")]);
        fleet.push(child);
        addrs.push(addr);
    }
    let mut daemon = Command::new(repro_exe())
        .arg("serve")
        .arg("--addr")
        .arg("127.0.0.1:0")
        .arg("--workers")
        .arg(addrs.join(","))
        .arg("--cache-dir")
        .arg(&cache)
        .arg("--resume")
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning repro serve");
    let stdout = daemon.stdout.take().expect("serve stdout is piped");
    let mut reader = BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("reading serve stdout");
        assert_ne!(n, 0, "serve exited before announcing its endpoint");
        if let Some(a) = line.strip_prefix("serving ") {
            break a.trim().to_string();
        }
    };

    // the jobs file: worker wire-frame encoding, keys computed
    // client-side (the daemon recomputes and must agree)
    let jobs = shared_job_list();
    let n_jobs = jobs.len();
    let jobs_path = tmp_dir("serve-jobs").with_extension("jsonl");
    let mut text = String::new();
    for job in &jobs {
        text.push_str(&wire::encode_job(&job.key(), job));
        text.push('\n');
    }
    std::fs::write(&jobs_path, text).unwrap();

    let r = ctl_json(&addr, "submit", &["--jobs", jobs_path.to_str().unwrap()]);
    let sweep = as_count(&r, "sweep").to_string();
    assert_eq!(as_count(&r, "total"), n_jobs);

    // cancel while most of the sweep is still queued
    let r = ctl_json(&addr, "cancel", &["--sweep", &sweep]);
    assert!(r.get("cancelled").unwrap().as_bool().unwrap());

    // poll status until the sweep settles (in-flight jobs finish)
    let deadline = Instant::now() + Duration::from_secs(60);
    let status = loop {
        let s = ctl_json(&addr, "status", &["--sweep", &sweep]);
        if s.get("done").unwrap().as_bool().unwrap() {
            break s;
        }
        assert!(Instant::now() < deadline, "cancelled sweep never settled: {}", s.dump());
        std::thread::sleep(Duration::from_millis(100));
    };
    let executed = as_count(&status, "executed");
    let cancelled = as_count(&status, "cancelled");
    assert!(cancelled > 0, "cancel must unqueue pending jobs: {}", status.dump());
    assert_eq!(as_count(&status, "failed"), 0, "status: {}", status.dump());
    // `failed` is a subset of `executed`, so with zero failures these
    // four partition the sweep
    assert_eq!(
        executed
            + cancelled
            + as_count(&status, "cache_hits")
            + as_count(&status, "deduped")
            + as_count(&status, "skipped"),
        n_jobs,
        "every job must be accounted for: {}",
        status.dump()
    );

    // in-flight jobs were cached; cancelled ones were not
    let stats = ctl_json(&addr, "cache-stats", &[]);
    assert_eq!(as_count(&stats, "records"), executed, "stats: {}", stats.dump());

    // status without --sweep lists every live sweep
    let all = ctl_json(&addr, "status", &[]);
    assert_eq!(all.get("sweeps").unwrap().as_arr().unwrap().len(), 1);

    // shutdown: ok reply, then a clean daemon exit
    let r = ctl_json(&addr, "shutdown", &[]);
    assert!(r.get("shutdown").unwrap().as_bool().unwrap());
    let exit = daemon.wait().expect("waiting for serve");
    assert!(exit.success(), "serve must exit cleanly after shutdown");

    // the persisted cache holds exactly the executed jobs, every key a
    // submitted one
    let lines = sorted_segment_lines(&cache);
    assert_eq!(lines.len(), executed);
    let expected: BTreeSet<String> = jobs.iter().map(|j| j.key()).collect();
    for line in &lines {
        assert!(expected.contains(&key_of_line(line)), "cache line for an unsubmitted key");
    }

    kill_fleet(fleet);
    let _ = std::fs::remove_file(&jobs_path);
    let _ = std::fs::remove_dir_all(&cache);
}
