//! Deterministic concurrency harness for the sharded run cache.
//!
//! The contract under test (ROADMAP "sharded sweeps"): N processes given
//! the *same* sweep and the same shared `--cache-dir`, each with
//! `--shard i/N`, drain disjoint deterministic slices into per-shard
//! segments, and the merged cache is **identical in content** to what a
//! single unsharded process produces — zero duplicate run keys — after
//! which `repro cache gc --older-than 0s` empties it.
//!
//! Everything runs on the mock backend (`Engine::with_backend` +
//! `MockBackend`), so no XLA artifacts are needed; pinning
//! `UMUP_CACHE_TS` makes cache lines
//! byte-for-byte reproducible, so the multi-process test compares raw
//! segment bytes (modulo line order — shard segments interleave freely).
//!
//! Two concurrency levels are covered:
//! * threads: four sharded [`Engine`]s in one process against one dir;
//! * processes: this test binary re-executes itself (the
//!   [`shard_child_entry`] test is the child main, selected via
//!   `UMUP_SHARD_ROLE`) four times concurrently, exactly like four
//!   `repro exp --shard i/4 --cache-dir D` invocations.

mod common;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use common::{
    cfg, det_mock_engine, dummy_corpus, dummy_manifest, key_of_line, shared_job_list,
    sorted_segment_lines,
};
use umup::engine::{
    gc, run_key, stats, Compactor, Engine, EngineConfig, EngineJob, GcOptions, RunCache,
    Shard,
};

// ---------------------------------------------------------- fixtures
// (the deterministic sweep + mock engine live in tests/common, shared
// with the driver harness in tests/drive.rs)

fn job_list() -> Vec<EngineJob> {
    shared_job_list()
}

fn job_keys(jobs: &[EngineJob]) -> Vec<String> {
    jobs.iter().map(|j| run_key(&j.manifest.name, &j.corpus, &j.config)).collect()
}

fn mock_engine(engine_cfg: EngineConfig, counter: Arc<AtomicUsize>) -> Engine {
    det_mock_engine(engine_cfg, counter)
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("umup-conc-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

// --------------------------------------------------- child process main

/// Child-process entrypoint for the multi-process test.  When run as a
/// normal test (no `UMUP_SHARD_ROLE` in the environment) it does
/// nothing; when this binary is re-executed by
/// [`four_shard_processes_equal_one_process_then_gc_empties`] it drains
/// the shared sweep as one sharded writer and records a marker file the
/// parent asserts on (so a silently-skipped child can't fake a pass).
#[test]
fn shard_child_entry() {
    if std::env::var("UMUP_SHARD_ROLE").as_deref() != Ok("drain") {
        return;
    }
    let dir = PathBuf::from(std::env::var("UMUP_SHARD_CACHE").expect("child cache dir"));
    let shard = match std::env::var("UMUP_SHARD_SPEC") {
        Ok(s) => Some(Shard::parse(&s).expect("child shard spec")),
        Err(_) => None,
    };
    let counter = Arc::new(AtomicUsize::new(0));
    let engine = mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(dir.clone()),
            resume: true,
            shard,
            ..EngineConfig::default()
        },
        Arc::clone(&counter),
    );
    let jobs = job_list();
    let n_jobs = jobs.len();
    let report = engine.run(jobs);
    assert_eq!(report.outcomes.len(), n_jobs);
    assert_eq!(report.failed, 0, "mock jobs never fail");
    for o in &report.outcomes {
        assert!(
            o.outcome.is_ok() || o.skipped,
            "child outcome must be ok or an explicit shard skip: {:?}",
            o.outcome.as_ref().err()
        );
    }
    drop(engine); // release the segment lock before the parent inspects
    let tag = shard.map_or("single".to_string(), |s| format!("{}-{}", s.index, s.count));
    std::fs::write(
        dir.join(format!("child-{tag}.ok")),
        format!("{} {}\n", report.executed, report.skipped),
    )
    .expect("writing child marker");
}

fn spawn_child(exe: &Path, dir: &Path, shard: Option<&str>) -> std::process::Child {
    let mut cmd = Command::new(exe);
    cmd.args(["shard_child_entry", "--exact", "--nocapture", "--test-threads", "1"])
        .env("UMUP_SHARD_ROLE", "drain")
        .env("UMUP_SHARD_CACHE", dir)
        .env("UMUP_CACHE_TS", "1700000000")
        .stdout(Stdio::null())
        .stderr(Stdio::inherit());
    if let Some(s) = shard {
        cmd.env("UMUP_SHARD_SPEC", s);
    }
    cmd.spawn().expect("spawning shard child")
}

// ---------------------------------------------------------------- tests

/// The acceptance test: 4 concurrent shard *processes* over one shared
/// cache dir produce a merged cache identical in content (byte-for-byte
/// per line, order-free) to the single-process sweep, with zero
/// duplicate run keys; `gc --older-than 0s` then empties the dir.
#[test]
fn four_shard_processes_equal_one_process_then_gc_empties() {
    let exe = std::env::current_exe().unwrap();
    let single = tmp_dir("proc-single");
    let sharded = tmp_dir("proc-sharded");

    // reference: one unsharded process
    let status = spawn_child(&exe, &single, None).wait().unwrap();
    assert!(status.success(), "single-process reference child failed");
    assert!(single.join("child-single.ok").exists(), "reference child never ran");

    // 4 shard processes, all live at once
    let children: Vec<_> =
        (0..4).map(|i| spawn_child(&exe, &sharded, Some(&format!("{i}/4")))).collect();
    for mut child in children {
        let status = child.wait().unwrap();
        assert!(status.success(), "shard child failed");
    }
    let mut executed_total = 0usize;
    for i in 0..4 {
        let marker = sharded.join(format!("child-{i}-4.ok"));
        assert!(marker.exists(), "shard {i} child never ran");
        let body = std::fs::read_to_string(&marker).unwrap();
        executed_total +=
            body.split_whitespace().next().unwrap().parse::<usize>().unwrap();
    }
    let jobs = job_list();
    assert_eq!(executed_total, jobs.len(), "shards must execute disjoint slices");

    // merged shard segments == the single-process segment, byte-for-byte
    // modulo ordering (UMUP_CACHE_TS pins the timestamp field)
    let single_lines = sorted_segment_lines(&single);
    let sharded_lines = sorted_segment_lines(&sharded);
    assert_eq!(single_lines.len(), jobs.len());
    assert_eq!(sharded_lines, single_lines, "merged cache must equal the unsharded run");

    // zero duplicate keys, and every key in the right segment
    let keys: BTreeSet<String> = sharded_lines.iter().map(|l| key_of_line(l)).collect();
    assert_eq!(keys.len(), jobs.len(), "duplicate run keys across segments");
    for seg in umup::engine::list_segments(&sharded).unwrap() {
        let name = seg.file_name().unwrap().to_str().unwrap().to_string();
        let idx: usize = name
            .strip_prefix("runs.")
            .and_then(|r| r.strip_suffix(".jsonl"))
            .expect("sharded dir holds only runs.<k>.jsonl segments")
            .parse()
            .unwrap();
        let shard = Shard { index: idx, count: 4 };
        for line in std::fs::read_to_string(&seg).unwrap().lines() {
            if line.trim().is_empty() {
                continue;
            }
            assert!(shard.owns(&key_of_line(line)), "foreign key in segment {name}");
        }
    }

    // a resumed unsharded cache sees the whole merged sweep
    let merged = RunCache::open(&sharded, true).unwrap();
    assert_eq!(merged.len(), jobs.len());
    drop(merged);

    // lifecycle: everything is older than "now - 0s", so gc empties it
    let report = gc(
        &sharded,
        &GcOptions { older_than: Some(Duration::from_secs(0)), ..Default::default() },
    )
    .unwrap();
    assert_eq!(report.pruned, jobs.len());
    assert_eq!(report.kept, 0);
    let st = stats(&sharded).unwrap();
    assert_eq!(st.unique_keys, 0);
    assert!(st.segments.is_empty(), "gc must remove emptied segments");
    assert!(RunCache::open(&sharded, true).unwrap().is_empty());

    let _ = std::fs::remove_dir_all(&single);
    let _ = std::fs::remove_dir_all(&sharded);
}

/// Same contract at thread granularity: four sharded engines in one
/// process, one shared dir, no duplicated execution, merged cache
/// content equal to the single-process run.
#[test]
fn four_shard_threads_partition_without_duplicate_execution() {
    let dir = tmp_dir("threads");
    let jobs = job_list();
    let n_jobs = jobs.len();
    let keys = job_keys(&jobs);
    let counter = Arc::new(AtomicUsize::new(0));

    std::thread::scope(|scope| {
        for i in 0..4 {
            let dir = dir.clone();
            let counter = Arc::clone(&counter);
            scope.spawn(move || {
                let engine = mock_engine(
                    EngineConfig {
                        workers: 2,
                        cache_dir: Some(dir),
                        resume: true,
                        shard: Some(Shard { index: i, count: 4 }),
                        ..EngineConfig::default()
                    },
                    counter,
                );
                let report = engine.run(job_list());
                assert_eq!(report.failed, 0);
                // each thread executes exactly its deterministic slice
                // (nothing was cached when all four start together —
                // late starters may instead see siblings' results as
                // cache hits, so only an upper bound holds per thread)
                assert!(report.executed + report.cache_hits + report.skipped == n_jobs);
            });
        }
    });

    // disjointness: 24 unique jobs -> exactly 24 executions total
    assert_eq!(counter.load(Ordering::SeqCst), n_jobs, "a job ran in two shards");
    let mut merged = RunCache::open(&dir, true).unwrap();
    assert_eq!(merged.len(), n_jobs);
    for key in &keys {
        assert!(merged.get(key).is_some(), "missing run {key}");
    }
    drop(merged);

    // a follow-up unsharded engine resolves the whole sweep from cache
    let c2 = Arc::new(AtomicUsize::new(0));
    let engine = mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(dir.clone()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::clone(&c2),
    );
    let report = engine.run(job_list());
    assert_eq!(report.cache_hits, n_jobs);
    assert_eq!(report.skipped, 0);
    assert_eq!(c2.load(Ordering::SeqCst), 0, "merged cache must satisfy every job");
    drop(engine);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A sharded engine executes exactly the keys it owns and reports the
/// rest as explicit skips (not failures), and the strict sweep view
/// names the owning shard in its error.
#[test]
fn sharded_engine_skips_foreign_jobs_with_owning_shard_named() {
    let jobs = job_list();
    let keys = job_keys(&jobs);
    let shard = Shard { index: 1, count: 3 };
    let owned = keys.iter().filter(|k| shard.owns(k)).count();
    assert!(owned < jobs.len(), "test needs a proper subset (got {owned})");

    let counter = Arc::new(AtomicUsize::new(0));
    let engine = mock_engine(
        EngineConfig { workers: 2, shard: Some(shard), ..EngineConfig::default() },
        Arc::clone(&counter),
    );
    let report = engine.run(jobs);
    assert_eq!(report.executed, owned);
    assert_eq!(counter.load(Ordering::SeqCst), owned);
    assert_eq!(report.skipped, keys.len() - owned);
    assert_eq!(report.failed, 0, "skips are not failures");
    assert_eq!(report.completed, owned);
    for (i, o) in report.outcomes.iter().enumerate() {
        if shard.owns(&keys[i]) {
            assert!(o.outcome.is_ok() && !o.skipped, "owned job {i} must run");
        } else {
            assert!(o.skipped, "foreign job {i} must be skipped");
            let err = o.outcome.as_ref().unwrap_err();
            let owner = Shard { index: 0, count: 3 }.index_of(&keys[i]);
            assert!(
                err.contains(&format!("belongs to shard {owner}/3")),
                "skip must name the owning shard: {err}"
            );
        }
    }
    let s = engine.stats();
    assert_eq!(s.skipped, keys.len() - owned);
    assert_eq!(s.failed, 0);

    // the strict view surfaces the skip as an error naming the owner
    let man = dummy_manifest("w32");
    let corpus = dummy_corpus();
    let foreign = (0..16)
        .map(|i| cfg(&format!("probe-{i}"), 10.0 + i as f64, 8))
        .find(|c| !shard.owns(&run_key("w32", &corpus, c)))
        .expect("some probe config lands outside the shard");
    let err = engine
        .run_sweep(&man, &corpus, &[umup::engine::SweepJob { config: foreign, tag: vec![] }])
        .unwrap_err()
        .to_string();
    assert!(err.contains("belongs to shard"), "{err}");
}

/// The sharded-drain convergence protocol `repro exp --shard` runs:
/// strict sweeps fail with [`umup::engine::SHARD_SKIP_MARKER`] while
/// foreign runs are outstanding, `refresh_cache` merges in what the
/// sibling published, and the retry completes as a pure cache-hit
/// replay — the production (`run_sweep`-based) experiment path, not
/// just the skip-tolerant `Engine::run` report.
#[test]
fn strict_sweeps_converge_via_cache_refresh_between_sharded_engines() {
    use umup::engine::{SweepJob, SHARD_SKIP_MARKER};

    let dir = tmp_dir("converge");
    let man = dummy_manifest("w32");
    let corpus = dummy_corpus();
    let sweep: Vec<SweepJob> = (0..8)
        .map(|i| SweepJob {
            config: cfg(&format!("s{i}"), 0.125 * (i + 1) as f64, 8),
            tag: vec![],
        })
        .collect();
    // precondition for a meaningful test: both shards own part of the
    // sweep (the mixed partition makes eta-only grids split; see
    // Shard::index_of)
    let split = sweep
        .iter()
        .filter(|j| {
            Shard { index: 0, count: 2 }.owns(&run_key("w32", &corpus, &j.config))
        })
        .count();
    assert!(split > 0 && split < sweep.len(), "degenerate partition: {split}/8");

    let counter = Arc::new(AtomicUsize::new(0));
    let engines: Vec<Engine> = (0..2)
        .map(|i| {
            mock_engine(
                EngineConfig {
                    workers: 2,
                    cache_dir: Some(dir.clone()),
                    resume: true,
                    shard: Some(Shard { index: i, count: 2 }),
                    ..EngineConfig::default()
                },
                Arc::clone(&counter),
            )
        })
        .collect();

    // round 1: each drains its slice; the strict view names the marker
    for engine in &engines {
        let err = engine.run_sweep(&man, &corpus, &sweep).unwrap_err().to_string();
        assert!(err.contains(SHARD_SKIP_MARKER), "{err}");
    }
    assert_eq!(counter.load(Ordering::SeqCst), sweep.len(), "slices must be disjoint");

    // round 2: refresh pulls the sibling's records; retry is pure hits
    for engine in &engines {
        assert!(engine.refresh_cache() > 0, "sibling results must become visible");
        let results = engine.run_sweep(&man, &corpus, &sweep).expect("converged replay");
        assert_eq!(results.len(), sweep.len());
        for (r, j) in results.iter().zip(&sweep) {
            assert_eq!(r.record.label, j.config.label);
        }
    }
    assert_eq!(counter.load(Ordering::SeqCst), sweep.len(), "retry must not re-execute");
    drop(engines);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Crash-safety at the engine level (satellite): a segment with a torn,
/// non-UTF-8 trailing line — a simulated mid-write kill — must resume
/// with a warning, re-running only the lost job, never aborting.
#[test]
fn resume_over_torn_segment_reruns_only_the_lost_job() {
    use std::io::Write as _;

    let dir = tmp_dir("torn-engine");
    let jobs = job_list();
    let n_jobs = jobs.len();
    let c1 = Arc::new(AtomicUsize::new(0));
    let engine = mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(dir.clone()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::clone(&c1),
    );
    engine.run(job_list());
    assert_eq!(c1.load(Ordering::SeqCst), n_jobs);
    drop(engine);

    // tear the last line: drop its tail, then append garbage bytes
    let seg = dir.join("runs.jsonl");
    let text = std::fs::read_to_string(&seg).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    let torn_key = key_of_line(lines[n_jobs - 1]);
    let keep = &lines[..n_jobs - 1];
    let mut f = std::fs::File::create(&seg).unwrap();
    for l in keep {
        writeln!(f, "{l}").unwrap();
    }
    let torn = &lines[n_jobs - 1][..lines[n_jobs - 1].len() / 2];
    f.write_all(torn.as_bytes()).unwrap();
    f.write_all(&[0xff, 0xfe, 0x80]).unwrap();
    drop(f);

    // resume: must not error, must re-run exactly the torn job
    let c2 = Arc::new(AtomicUsize::new(0));
    let engine = mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(dir.clone()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::clone(&c2),
    );
    let report = engine.run(job_list());
    assert_eq!(report.failed, 0);
    assert_eq!(report.cache_hits, n_jobs - 1);
    assert_eq!(c2.load(Ordering::SeqCst), 1, "only the torn job re-runs");
    assert_eq!(engine.cache_len(), n_jobs);
    drop(engine);

    // and the re-run record landed back in the cache on disk
    let mut merged = RunCache::open(&dir, true).unwrap();
    assert!(merged.get(&torn_key).is_some(), "torn job must be re-recorded");
    assert_eq!(merged.len(), n_jobs);
    drop(merged);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Background tiered merges under a live concurrent writer (public-API
/// view of the compaction contract): while a sharded writer holds its
/// segment lock and keeps appending, [`Compactor::step`] folds the
/// *finished* segments — never the writer's, never by waiting on its
/// lock — and once the writer is gone the remaining segments converge
/// to one, with every record from both sides still addressable.
#[test]
fn tiered_merges_fold_finished_segments_around_a_live_writer() {
    fn tier_rec(label: &str) -> umup::train::RunRecord {
        umup::train::RunRecord {
            label: label.to_string(),
            train_curve: vec![(8, 2.5), (16, 2.0)],
            valid_curve: vec![(16, 2.1)],
            final_valid_loss: 2.1,
            rms_curves: std::collections::BTreeMap::new(),
            final_rms: vec![],
            diverged: false,
            wall_seconds: 0.1,
        }
    }
    fn tier_key(i: u64) -> String {
        format!("{i:016x}")
    }

    let dir = tmp_dir("tier-merge");
    // three finished similar-sized segments (their writers are gone)
    let mut expected: Vec<String> = Vec::new();
    for s in 1..=3usize {
        let mut c =
            RunCache::open_sharded(&dir, Some(Shard { index: s, count: 4 }), true).unwrap();
        for i in 0..8u64 {
            let k = tier_key(((s as u64) << 8) | i);
            c.put(&k, "tier", &tier_rec(&format!("seg{s}-{i}"))).unwrap();
            expected.push(k);
        }
    }

    // a live writer on runs.0.jsonl, lock held across every step below
    let mut writer =
        RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 4 }), true).unwrap();
    let mut next = 0x9000u64;
    let mut live_put = |w: &mut RunCache, expected: &mut Vec<String>| {
        let k = tier_key(next);
        next += 1;
        w.put(&k, "tier", &tier_rec("live")).unwrap();
        expected.push(k);
    };
    live_put(&mut writer, &mut expected);

    let compactor = Compactor::new(&dir);
    let mut reports = Vec::new();
    // steps interleaved with appends: each merge must skip the locked
    // segment (returning instead of blocking) and fold only finished ones
    while let Some(r) = compactor.step().unwrap() {
        assert!(
            !r.inputs.iter().any(|n| n == "runs.0.jsonl"),
            "merged the live writer's segment: {:?}",
            r.inputs
        );
        live_put(&mut writer, &mut expected);
        reports.push(r);
    }
    assert!(!reports.is_empty(), "finished segments must merge around the live lock");
    live_put(&mut writer, &mut expected);
    drop(writer); // lock released; the writer's segment is now finished too

    while compactor.step().unwrap().is_some() {}
    let segs = umup::engine::list_segments(&dir).unwrap();
    assert_eq!(segs.len(), 1, "all segments converge once the writer is gone: {segs:?}");

    // nothing lost on either side of the concurrency
    let mut merged = RunCache::open(&dir, true).unwrap();
    assert_eq!(merged.len(), expected.len());
    for k in &expected {
        assert!(merged.get(k).is_some(), "missing record {k} after tier merges");
    }
    drop(merged);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two writers cannot share a segment: the same shard index (or the
/// unsharded segment) is locked against a live second opener, while
/// distinct shard indices coexist.
#[test]
fn segment_locks_exclude_same_shard_writers_only() {
    let dir = tmp_dir("locks");
    let a = RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true).unwrap();
    // same segment -> refused while the first writer is alive
    let err = RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true)
        .unwrap_err()
        .to_string();
    assert!(err.contains("locked by live process"), "{err}");
    // different segment -> fine concurrently
    let b = RunCache::open_sharded(&dir, Some(Shard { index: 1, count: 2 }), true).unwrap();
    drop(a);
    drop(b);
    // both released: reopening either now succeeds
    RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
