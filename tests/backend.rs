//! Integration suite for the execution-backend layer: the
//! `ProcessBackend` + `repro worker` wire protocol, its crash
//! supervision, and the contract that an out-of-process drain is
//! **byte-identical in the run cache** to the in-process one.
//!
//! No XLA needed: the children are `repro worker --mock` (the repro
//! binary itself, located via `CARGO_BIN_EXE_repro`), whose executor is
//! the same canonical deterministic mock (`umup::engine::det_record`)
//! the in-process `MockBackend` uses — so byte equality is a real
//! assertion about the wire/cache codec, not luck.  `UMUP_CACHE_TS` is
//! pinned in this process (the *parent* writes all cache lines), and
//! failure injection in the children is armed through the
//! `UMUP_MOCK_FAIL` / `UMUP_MOCK_FAIL_ONCE` env knobs documented in
//! `main.rs`.

mod common;

use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::atomic::AtomicUsize;
use std::sync::Arc;

use common::{det_mock_engine, key_of_line, shared_job_list, sorted_segment_lines};
use umup::engine::{Engine, EngineConfig, ProcessBackend, Shard};

fn repro_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_repro"))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("umup-backend-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A fresh (guaranteed-absent) one-shot failure marker path.
fn fresh_marker(tag: &str) -> PathBuf {
    let m = tmp_dir(tag).with_extension("once");
    let _ = std::fs::remove_file(&m);
    m
}

/// Pin the cache timestamp so segment lines are byte-reproducible.
/// Process-wide, but every test in this binary pins the same value, so
/// parallel test threads cannot disagree.
fn pin_cache_ts() {
    std::env::set_var("UMUP_CACHE_TS", "1700000000");
}

/// A mock-worker process backend, optionally with one-shot failure
/// injection: `fail` is the `UMUP_MOCK_FAIL` mode, `once` the marker
/// path that arms it exactly once across the whole fleet (`None` =
/// fail on every job).
fn mock_worker_backend(fail: Option<&str>, once: Option<&Path>) -> ProcessBackend {
    let exe = repro_exe();
    let fail = fail.map(str::to_string);
    let once = once.map(Path::to_path_buf);
    ProcessBackend::new(move |_worker| {
        let mut cmd = Command::new(&exe);
        cmd.arg("worker").arg("--mock");
        if let Some(mode) = &fail {
            cmd.env("UMUP_MOCK_FAIL", mode);
        }
        if let Some(marker) = &once {
            cmd.env("UMUP_MOCK_FAIL_ONCE", marker);
        }
        cmd
    })
}

/// The acceptance test: a 4-worker `ProcessBackend` drain of the shared
/// sweep — with one child crash injected mid-job — produces a run cache
/// byte-identical to the single-process in-process run, with the
/// crashed job re-dispatched (not failed) and the restart accounted.
#[test]
fn process_backend_drain_with_crash_is_byte_identical_to_in_process() {
    pin_cache_ts();
    let in_dir = tmp_dir("inproc");
    let proc_dir = tmp_dir("proc");
    let marker = fresh_marker("crash-marker");
    let jobs = shared_job_list();
    let n_jobs = jobs.len();

    // reference: in-process deterministic mock
    let counter = Arc::new(AtomicUsize::new(0));
    let engine = det_mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(in_dir.clone()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::clone(&counter),
    );
    let report = engine.run(shared_job_list());
    assert_eq!(report.completed, n_jobs);
    drop(engine);

    // out-of-process: 4 worker children, one armed to crash before its
    // first reply (exactly once across the fleet, restarts included)
    let backend = Arc::new(
        mock_worker_backend(Some("crash-before-reply"), Some(&marker)).with_max_restarts(2),
    );
    let engine = Engine::with_backend(
        EngineConfig {
            workers: 4,
            cache_dir: Some(proc_dir.clone()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::clone(&backend) as Arc<dyn umup::engine::Backend>,
    )
    .unwrap();
    let report = engine.run(shared_job_list());
    assert_eq!(report.completed, n_jobs, "crashed job must be re-dispatched, not lost");
    assert_eq!(report.failed, 0);
    assert_eq!(report.executed, n_jobs);
    drop(engine);

    assert!(marker.exists(), "the crash injection never fired");
    assert!(backend.restarts() >= 1, "the crashed child must have been restarted");

    let reference = sorted_segment_lines(&in_dir);
    let processed = sorted_segment_lines(&proc_dir);
    assert_eq!(reference.len(), n_jobs);
    assert_eq!(
        processed, reference,
        "process-backend cache must be byte-identical to the in-process one"
    );

    let _ = std::fs::remove_file(&marker);
    let _ = std::fs::remove_dir_all(&in_dir);
    let _ = std::fs::remove_dir_all(&proc_dir);
}

/// Sharding composes with the process backend: two sharded engines
/// (each with its own worker children, one crash injected in the first)
/// drain disjoint slices into one cache dir whose merged content equals
/// the unsharded in-process run, with zero duplicate keys.
#[test]
fn sharded_process_backend_drain_merges_byte_identically() {
    pin_cache_ts();
    let in_dir = tmp_dir("shard-inproc");
    let proc_dir = tmp_dir("shard-proc");
    let marker = fresh_marker("shard-crash-marker");
    let jobs = shared_job_list();
    let n_jobs = jobs.len();

    let counter = Arc::new(AtomicUsize::new(0));
    let engine = det_mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(in_dir.clone()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::clone(&counter),
    );
    engine.run(shared_job_list());
    drop(engine);

    for index in 0..2usize {
        // only the first shard's fleet is armed; the marker also keeps
        // the injection single-shot if both were
        let fail = if index == 0 { Some("crash-before-reply") } else { None };
        let backend = Arc::new(mock_worker_backend(fail, Some(&marker)));
        let engine = Engine::with_backend(
            EngineConfig {
                workers: 2,
                cache_dir: Some(proc_dir.clone()),
                resume: true,
                shard: Some(Shard { index, count: 2 }),
                ..EngineConfig::default()
            },
            backend,
        )
        .unwrap();
        let report = engine.run(shared_job_list());
        assert_eq!(report.failed, 0, "shard {index} must not fail jobs");
        assert_eq!(
            report.executed + report.cache_hits + report.skipped,
            n_jobs,
            "shard {index} must account for every job"
        );
        drop(engine);
    }
    assert!(marker.exists(), "the crash injection never fired");

    let reference = sorted_segment_lines(&in_dir);
    let merged = sorted_segment_lines(&proc_dir);
    assert_eq!(merged, reference, "merged sharded drain must equal the unsharded run");
    let keys: std::collections::BTreeSet<String> =
        merged.iter().map(|l| key_of_line(l)).collect();
    assert_eq!(keys.len(), n_jobs, "duplicate run keys across shard segments");

    let _ = std::fs::remove_file(&marker);
    let _ = std::fs::remove_dir_all(&in_dir);
    let _ = std::fs::remove_dir_all(&proc_dir);
}

/// Garbage on a child's stdout is a transport failure: the child is
/// replaced and the in-flight job re-dispatched — never a wedged engine
/// or a lost job.
#[test]
fn garbage_on_stdout_restarts_the_child_and_recovers_the_job() {
    pin_cache_ts();
    let marker = fresh_marker("garbage-marker");
    let backend = Arc::new(mock_worker_backend(Some("garbage"), Some(&marker)));
    let engine = Engine::with_backend(
        EngineConfig { workers: 1, ..EngineConfig::default() },
        Arc::clone(&backend) as Arc<dyn umup::engine::Backend>,
    )
    .unwrap();
    let report = engine.run(shared_job_list().into_iter().take(4).collect());
    assert_eq!(report.completed, 4);
    assert_eq!(report.failed, 0);
    assert!(marker.exists());
    assert_eq!(backend.restarts(), 1, "garbage must cost exactly one restart");
    let _ = std::fs::remove_file(&marker);
}

/// A torn frame (length prefix promising more bytes than arrive before
/// the child dies) is survived the same way.
#[test]
fn truncated_frame_restarts_the_child_and_recovers_the_job() {
    pin_cache_ts();
    let marker = fresh_marker("truncate-marker");
    let backend = Arc::new(mock_worker_backend(Some("truncate"), Some(&marker)));
    let engine = Engine::with_backend(
        EngineConfig { workers: 1, ..EngineConfig::default() },
        Arc::clone(&backend) as Arc<dyn umup::engine::Backend>,
    )
    .unwrap();
    let report = engine.run(shared_job_list().into_iter().take(4).collect());
    assert_eq!(report.completed, 4);
    assert_eq!(report.failed, 0);
    assert!(marker.exists());
    assert_eq!(backend.restarts(), 1);
    let _ = std::fs::remove_file(&marker);
}

/// A child that exits cleanly *between* jobs (reply delivered, then
/// gone) is respawned for the next job; nothing is re-run or lost.
#[test]
fn child_exiting_between_jobs_is_respawned() {
    pin_cache_ts();
    let marker = fresh_marker("between-marker");
    let backend = Arc::new(mock_worker_backend(Some("crash-after-reply"), Some(&marker)));
    let engine = Engine::with_backend(
        EngineConfig { workers: 1, ..EngineConfig::default() },
        Arc::clone(&backend) as Arc<dyn umup::engine::Backend>,
    )
    .unwrap();
    let report = engine.run(shared_job_list().into_iter().take(4).collect());
    assert_eq!(report.completed, 4, "every job completes despite the exit");
    assert_eq!(report.failed, 0);
    assert_eq!(report.executed, 4, "the replied-then-exit job must not re-run");
    assert!(marker.exists());
    assert_eq!(backend.restarts(), 1);
    let _ = std::fs::remove_file(&marker);
}

/// A child that *always* crashes exhausts the worker's bounded restart
/// budget: its jobs come back as normal per-job `Err` outcomes carrying
/// the child's stderr, and the engine itself stays alive and drainable.
#[test]
fn restart_budget_exhaustion_reports_normal_err_outcomes() {
    pin_cache_ts();
    // no once-marker: every armed child crashes on its first job
    let backend = Arc::new(
        mock_worker_backend(Some("crash-before-reply"), None).with_max_restarts(1),
    );
    let engine = Engine::with_backend(
        EngineConfig { workers: 1, ..EngineConfig::default() },
        Arc::clone(&backend) as Arc<dyn umup::engine::Backend>,
    )
    .unwrap();
    let report = engine.run(shared_job_list().into_iter().take(3).collect());
    assert_eq!(report.failed, 3, "all jobs on the crashing worker must fail");
    assert_eq!(report.completed, 0);
    let errs: Vec<&String> = report
        .outcomes
        .iter()
        .map(|o| o.outcome.as_ref().unwrap_err())
        .collect();
    assert!(
        errs.iter().any(|e| e.contains("injected crash")),
        "a failure outcome must carry the child's stderr tail: {errs:?}"
    );
    assert!(
        errs.iter().any(|e| e.contains("restart budget exhausted")),
        "post-budget jobs must name the exhausted budget: {errs:?}"
    );
    assert_eq!(backend.restarts(), 1, "budget 1 allows exactly one restart");
}

/// Regression: the health probe (and the executor handshake) drain the
/// child's stderr *concurrently* with the hello wait.  A chatty worker
/// that writes far more than the OS pipe buffer (~64 KiB) before its
/// hello frame would deadlock a sequential probe — the child blocked on
/// its full stderr pipe, the parent blocked on a silent stdout.
#[test]
fn health_probe_survives_chatty_worker_stderr() {
    pin_cache_ts();
    let exe = repro_exe();
    let backend = Arc::new(ProcessBackend::new(move |_worker| {
        let mut cmd = Command::new(&exe);
        cmd.arg("worker").arg("--mock");
        // ~3x the pipe buffer, flushed before the hello frame
        cmd.env("UMUP_MOCK_STDERR_SPAM", "200000");
        cmd
    }));
    let engine = Engine::with_backend(
        EngineConfig { workers: 1, ..EngineConfig::default() },
        Arc::clone(&backend) as Arc<dyn umup::engine::Backend>,
    )
    .expect("a chatty-but-healthy worker must pass the health probe");
    let report = engine.run(shared_job_list().into_iter().take(3).collect());
    assert_eq!(report.completed, 3);
    assert_eq!(report.failed, 0);
    assert_eq!(backend.restarts(), 0, "stderr spam must not be mistaken for a crash");
}

/// The health probe runs at engine construction and rejects a worker
/// command that does not speak the protocol — no jobs are ever sent to
/// a wrong binary.
#[test]
fn health_probe_rejects_a_non_worker_command() {
    let exe = repro_exe();
    let backend = Arc::new(ProcessBackend::new(move |_worker| {
        // `repro definitely-not-a-command` prints usage text — not a
        // hello frame
        let mut cmd = Command::new(&exe);
        cmd.arg("definitely-not-a-command");
        cmd
    }));
    let err = Engine::with_backend(
        EngineConfig { workers: 1, ..EngineConfig::default() },
        backend as Arc<dyn umup::engine::Backend>,
    )
    .err()
    .expect("a non-worker command must fail the health probe");
    let msg = format!("{err:#}");
    assert!(msg.contains("health"), "{msg}");
}
