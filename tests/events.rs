//! Integration suite for the `repro-events` telemetry subsystem.
//!
//! Four layers, bottom-up:
//!
//! * **Codec goldens** — the serialized form of every [`Event`] variant
//!   is pinned byte-for-byte, and the additive-evolution contract
//!   (unknown fields ignored, unknown types mapped to
//!   [`Event::Unknown`]) is exercised explicitly.  A diff in these
//!   strings is a schema break: additions are fine, renames are not.
//! * **Bus contract** — publish never blocks: a full subscriber drops
//!   events into the counted [`EventBus::dropped`] metric, and an
//!   unsubscribed bus is inert.
//! * **Partition invariant** — across executed/hit/dup/skip/cancelled
//!   sweeps on the deterministic mock engine, the `job_done` stream
//!   exactly partitions each sweep's total and agrees with the final
//!   `EngineReport`.  The same invariant is then asserted end-to-end on
//!   a crash-injected 4-shard `engine::driver::drive` whose children
//!   stream JSONL event files (the `--progress jsonl:PATH` plumbing)
//!   that the driver tails into one merged stream.
//! * **Wire** — a live `repro serve` daemon re-serves its engine's bus
//!   through the `events` RPC verb; both a raw socket client and the
//!   `repro ctl watch` CLI tail it.
//!
//! Everything runs on the mock executor; no XLA artifacts are needed.

mod common;

use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::AtomicUsize;
use std::sync::Arc;
use std::time::Duration;

use common::{det_mock_engine, key_of_line, shared_job_list, sorted_segment_lines};
use umup::engine::backend::wire;
use umup::engine::driver::{drive, DriveConfig};
use umup::engine::events::EVENTS_VERSION;
use umup::engine::{
    EngineConfig, Envelope, Event, EventBus, JobStatus, Shard, SweepCounters,
};
use umup::util::Json;

const TS: u64 = 1_700_000_000_000;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("umup-events-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn env(seq: u64, shard: Option<usize>, event: Event) -> Envelope {
    Envelope { v: EVENTS_VERSION, seq, ts_ms: TS, shard, event }
}

// ------------------------------------------------------ codec goldens

/// Every variant's serialized line, pinned exactly.  Keys are
/// alphabetical (the `Json` dumper's order), `shard` appears only on
/// tagged envelopes, and optional fields are omitted rather than
/// nulled.  Changing any of these strings is a breaking schema change
/// and needs an `EVENTS_VERSION` bump; *adding* variants or fields only
/// extends this list.
#[test]
fn golden_envelope_lines_are_pinned() {
    let done = |idx: usize, key: &str, label: &str, status, ok, error: Option<&str>,
                duration_ms, worker| Event::JobDone {
        sweep: 7,
        idx,
        key: key.to_string(),
        manifest: "w32".to_string(),
        label: label.to_string(),
        status,
        ok,
        error: error.map(str::to_string),
        duration_ms,
        worker,
    };
    let cases: Vec<(Envelope, &str)> = vec![
        (
            env(0, None, Event::SweepStarted { sweep: 7, total: 24 }),
            r#"{"seq":0,"sweep":7,"total":24,"ts":1700000000000,"type":"sweep_started","v":1}"#,
        ),
        (
            env(
                1,
                None,
                Event::SweepFinished {
                    sweep: 7,
                    counters: SweepCounters {
                        total: 24,
                        executed: 6,
                        hits: 12,
                        dups: 3,
                        skips: 2,
                        cancelled: 1,
                        failed: 1,
                    },
                    duration_ms: 1234,
                },
            ),
            r#"{"counters":{"cancelled":1,"dups":3,"executed":6,"failed":1,"hits":12,"skips":2,"total":24},"duration_ms":1234,"seq":1,"sweep":7,"ts":1700000000000,"type":"sweep_finished","v":1}"#,
        ),
        (
            env(
                2,
                Some(1),
                Event::JobQueued {
                    sweep: 7,
                    idx: 3,
                    key: "00aa".to_string(),
                    manifest: "w32".to_string(),
                    label: "w32-lr1".to_string(),
                },
            ),
            r#"{"idx":3,"key":"00aa","label":"w32-lr1","manifest":"w32","seq":2,"shard":1,"sweep":7,"ts":1700000000000,"type":"job_queued","v":1}"#,
        ),
        (
            env(
                3,
                Some(1),
                done(3, "00aa", "w32-lr1", JobStatus::Executed, true, None, Some(42), Some(0)),
            ),
            r#"{"duration_ms":42,"idx":3,"key":"00aa","label":"w32-lr1","manifest":"w32","ok":true,"seq":3,"shard":1,"status":"executed","sweep":7,"ts":1700000000000,"type":"job_done","v":1,"worker":0}"#,
        ),
        (
            env(
                4,
                None,
                done(
                    4,
                    "00bb",
                    "w32-lr2",
                    JobStatus::Executed,
                    false,
                    Some("boom"),
                    Some(7),
                    Some(1),
                ),
            ),
            r#"{"duration_ms":7,"error":"boom","idx":4,"key":"00bb","label":"w32-lr2","manifest":"w32","ok":false,"seq":4,"status":"executed","sweep":7,"ts":1700000000000,"type":"job_done","v":1,"worker":1}"#,
        ),
        (
            env(5, None, done(5, "00cc", "w32-lr3", JobStatus::Hit, true, None, None, None)),
            r#"{"idx":5,"key":"00cc","label":"w32-lr3","manifest":"w32","ok":true,"seq":5,"status":"hit","sweep":7,"ts":1700000000000,"type":"job_done","v":1}"#,
        ),
        (
            env(6, None, Event::WorkerSpawned { worker: 2, window: 4 }),
            r#"{"seq":6,"ts":1700000000000,"type":"worker_spawned","v":1,"window":4,"worker":2}"#,
        ),
        (
            env(
                7,
                None,
                Event::WorkerRestarted {
                    worker: 2,
                    restarts_left: 1,
                    stderr: "panic: boom".to_string(),
                },
            ),
            r#"{"restarts_left":1,"seq":7,"stderr":"panic: boom","ts":1700000000000,"type":"worker_restarted","v":1,"worker":2}"#,
        ),
        (
            env(8, None, Event::WorkerBudgetExhausted { worker: 2, stderr: String::new() }),
            r#"{"seq":8,"stderr":"","ts":1700000000000,"type":"worker_budget_exhausted","v":1,"worker":2}"#,
        ),
        (
            env(9, None, Event::CacheRefresh { new_keys: 4, total_keys: 20 }),
            r#"{"new_keys":4,"seq":9,"total_keys":20,"ts":1700000000000,"type":"cache_refresh","v":1}"#,
        ),
        (
            env(
                10,
                None,
                Event::CacheCompaction {
                    inputs: 3,
                    output: "runs.t1.0.jsonl".to_string(),
                    entries: 24,
                    deduped: 2,
                },
            ),
            r#"{"deduped":2,"entries":24,"inputs":3,"output":"runs.t1.0.jsonl","seq":10,"ts":1700000000000,"type":"cache_compaction","v":1}"#,
        ),
        (
            env(11, None, Event::ShardSpawned { shard: 1, attempt: 1 }),
            r#"{"attempt":1,"seq":11,"shard":1,"ts":1700000000000,"type":"shard_spawned","v":1}"#,
        ),
        (
            env(
                12,
                None,
                Event::ShardExit { shard: 1, ok: false, detail: "exit status: 3".to_string() },
            ),
            r#"{"detail":"exit status: 3","ok":false,"seq":12,"shard":1,"ts":1700000000000,"type":"shard_exit","v":1}"#,
        ),
        (
            env(13, None, Event::ShardRestarted { shard: 1, attempt: 2, max_attempts: 3 }),
            r#"{"attempt":2,"max_attempts":3,"seq":13,"shard":1,"ts":1700000000000,"type":"shard_restarted","v":1}"#,
        ),
        (
            env(
                14,
                None,
                Event::Snapshot {
                    done: 12,
                    total: Some(24),
                    cached_keys: 12,
                    segments: 4,
                    throughput: 2.5,
                    eta_s: Some(4.75),
                    pool_hits: 9,
                    pool_steals: 1,
                    dropped: 0,
                },
            ),
            r#"{"cached_keys":12,"done":12,"dropped":0,"eta_s":4.75,"pool_hits":9,"pool_steals":1,"segments":4,"seq":14,"throughput":2.5,"total":24,"ts":1700000000000,"type":"snapshot","v":1}"#,
        ),
        (
            env(15, None, Event::WorkerStalled { worker: 2, timeout_ms: 5000, pending: 3 }),
            r#"{"pending":3,"seq":15,"timeout_ms":5000,"ts":1700000000000,"type":"worker_stalled","v":1,"worker":2}"#,
        ),
    ];
    for (envelope, golden) in &cases {
        assert_eq!(
            &envelope.line(),
            golden,
            "pinned serialization changed for {:?}",
            envelope.event.kind()
        );
        // round trip; the shard_* driver events share their `shard`
        // key with the envelope header, so the header comes back
        // populated there — compare the event payload in all cases and
        // the full envelope everywhere else
        let parsed = Envelope::parse(golden).expect("golden line must parse");
        assert_eq!(parsed.event, envelope.event, "round trip of {golden}");
        if !golden.contains("\"type\":\"shard_") {
            assert_eq!(&parsed, envelope, "round trip of {golden}");
        }
    }

    // pass-through: a child line re-emitted by the driver is the
    // child's own envelope, verbatim — no double wrapping
    let inner = cases[0].1.to_string();
    let fwd = env(99, None, Event::ChildLine { line: inner.clone() });
    assert_eq!(fwd.line(), inner);
    assert!(matches!(
        Envelope::parse(&fwd.line()).unwrap().event,
        Event::SweepStarted { sweep: 7, total: 24 }
    ));
}

/// The additive-evolution guard: a reader of today's schema must tail
/// tomorrow's stream losslessly — unknown fields are ignored, unknown
/// event types decode to [`Event::Unknown`] with the header intact.
#[test]
fn parse_tolerates_future_fields_and_types() {
    // a known type with an extra (future) field parses identically
    let known = r#"{"idx":3,"key":"00aa","label":"w32-lr1","manifest":"w32","seq":2,"sweep":7,"ts":1700000000000,"type":"job_queued","v":1,"zzz_future_field":true}"#;
    let parsed = Envelope::parse(known).expect("extra fields must be ignored");
    assert!(matches!(parsed.event, Event::JobQueued { sweep: 7, idx: 3, .. }));

    // a pre-pipelining worker_spawned line (no `window` field) still
    // parses: absent window means lockstep
    let old = r#"{"seq":6,"ts":1700000000000,"type":"worker_spawned","v":1,"worker":2}"#;
    let parsed = Envelope::parse(old).expect("pre-window streams must parse");
    assert_eq!(parsed.event, Event::WorkerSpawned { worker: 2, window: 1 });

    // an unknown type decodes to Unknown, header preserved
    let future = r#"{"flux":0.5,"seq":41,"shard":2,"ts":1700000000000,"type":"warp_core_breach","v":1}"#;
    let parsed = Envelope::parse(future).expect("unknown types must not error");
    assert_eq!(parsed.seq, 41);
    assert_eq!(parsed.shard, Some(2));
    assert_eq!(parsed.event, Event::Unknown { kind: "warp_core_breach".to_string() });

    // malformed JSON still errors — tolerance is not laxness
    assert!(Envelope::parse("{not json").is_err());
}

// ------------------------------------------------------- bus contract

#[test]
fn bus_overflow_drops_are_counted_not_blocking() {
    let bus = EventBus::new();
    // inert until subscribed: publish is a no-op that stamps nothing
    bus.publish(Event::WorkerSpawned { worker: 0, window: 1 });
    assert!(!bus.is_active());
    assert_eq!(bus.published(), 0);
    assert_eq!(bus.dropped(), 0);

    let stream = bus.subscribe(2);
    assert!(bus.is_active());
    for w in 0..10 {
        bus.publish(Event::WorkerSpawned { worker: w, window: 1 });
    }
    // capacity 2: the first two buffered, the other eight dropped and
    // counted — publish returned every time without blocking
    assert_eq!(bus.published(), 10);
    assert_eq!(bus.dropped(), 8);
    let first = stream.recv().expect("first buffered event");
    let second = stream.recv().expect("second buffered event");
    assert_eq!((first.seq, second.seq), (0, 1), "delivery preserves publish order");

    // drained capacity accepts new events again; the seq gap exposes
    // the drops to the consumer
    bus.publish(Event::WorkerSpawned { worker: 99, window: 1 });
    assert_eq!(bus.dropped(), 8);
    let next = stream.recv().expect("post-drain event");
    assert_eq!(next.seq, 10);
    assert!(matches!(next.event, Event::WorkerSpawned { worker: 99, .. }));

    // end-of-stream: once every bus clone is gone the stream ends
    drop(bus);
    assert!(stream.recv().is_none(), "stream must end when the bus is dropped");
}

// ------------------------------------------- partition vs EngineReport

/// Tally of `job_done` statuses within one sweep's event segment.
#[derive(Default, Debug, PartialEq, Eq)]
struct Tally {
    queued: usize,
    executed: usize,
    hits: usize,
    dups: usize,
    skips: usize,
    cancelled: usize,
    finished: Option<(SweepCounters, usize)>,
}

fn tally(segment: &[Envelope]) -> Tally {
    let mut t = Tally::default();
    for e in segment {
        match &e.event {
            Event::JobQueued { .. } => t.queued += 1,
            Event::JobDone { status, .. } => match status {
                JobStatus::Executed => t.executed += 1,
                JobStatus::Hit => t.hits += 1,
                JobStatus::Dup => t.dups += 1,
                JobStatus::Skip => t.skips += 1,
                JobStatus::Cancelled => t.cancelled += 1,
            },
            Event::SweepFinished { counters, .. } => {
                let total = counters.total;
                t.finished = Some((*counters, total));
            }
            _ => {}
        }
    }
    t
}

/// Split an in-order event list into per-sweep segments (each starting
/// at its `sweep_started`).
fn split_sweeps(events: &[Envelope]) -> Vec<&[Envelope]> {
    let starts: Vec<usize> = events
        .iter()
        .enumerate()
        .filter(|(_, e)| matches!(e.event, Event::SweepStarted { .. }))
        .map(|(i, _)| i)
        .collect();
    starts
        .iter()
        .enumerate()
        .map(|(k, &s)| {
            let end = starts.get(k + 1).copied().unwrap_or(events.len());
            &events[s..end]
        })
        .collect()
}

fn assert_segment_matches(
    segment: &[Envelope],
    report: &umup::engine::EngineReport,
    what: &str,
) {
    let total = match &segment[0].event {
        Event::SweepStarted { total, .. } => *total,
        other => panic!("{what}: segment must open with sweep_started, got {other:?}"),
    };
    assert_eq!(total, report.outcomes.len(), "{what}: sweep total");
    let t = tally(segment);
    assert_eq!(t.queued, total, "{what}: every job must be announced as queued");
    assert_eq!(
        t.executed + t.hits + t.dups + t.skips + t.cancelled,
        total,
        "{what}: job_done statuses must exactly partition the sweep: {t:?}"
    );
    assert_eq!(t.executed, report.executed, "{what}: executed");
    assert_eq!(t.hits, report.cache_hits, "{what}: cache hits");
    assert_eq!(t.dups, report.deduped, "{what}: dups");
    assert_eq!(t.skips, report.skipped, "{what}: skips");
    assert_eq!(t.cancelled, report.cancelled, "{what}: cancelled");
    let (counters, _) = t.finished.unwrap_or_else(|| panic!("{what}: no sweep_finished event"));
    assert_eq!(counters.total, total, "{what}: finished total");
    assert_eq!(counters.executed, report.executed, "{what}: finished executed");
    assert_eq!(counters.hits, report.cache_hits, "{what}: finished hits");
    assert_eq!(counters.dups, report.deduped, "{what}: finished dups");
    assert_eq!(counters.skips, report.skipped, "{what}: finished skips");
    assert_eq!(counters.cancelled, report.cancelled, "{what}: finished cancelled");
    assert_eq!(counters.failed, report.failed, "{what}: finished failed");
}

/// The partition invariant on the deterministic mock engine, across
/// every status: a fresh drain (executed + dups), a resumed re-drain
/// (hits), a sharded drain (skips), and a cancelled sweep — each
/// sweep's `job_done` stream exactly partitions its total and agrees
/// with the returned `EngineReport`.
#[test]
fn job_done_stream_partitions_every_sweep_and_matches_the_report() {
    std::env::set_var("UMUP_CACHE_TS", "1700000000");
    let dir = tmp_dir("partition");
    let dir_cancel = tmp_dir("partition-cancel");
    let bus = EventBus::new();
    let stream = bus.subscribe(4096);
    let base = EngineConfig {
        workers: 2,
        cache_dir: Some(dir.clone()),
        resume: true,
        events: Some(bus.clone()),
        ..EngineConfig::default()
    };

    // sweep 1: fresh cache, with 3 duplicated jobs appended
    let mut jobs = shared_job_list();
    let mut extra = shared_job_list();
    extra.truncate(3);
    jobs.extend(extra);
    let engine = det_mock_engine(base.clone(), Arc::new(AtomicUsize::new(0)));
    let fresh = engine.run(jobs);
    assert_eq!(fresh.executed, 24);
    assert_eq!(fresh.deduped, 3);
    drop(engine);

    // sweep 2: identical drain resumes from the cache — all hits
    let engine = det_mock_engine(base.clone(), Arc::new(AtomicUsize::new(0)));
    let resumed = engine.run(shared_job_list());
    assert_eq!(resumed.cache_hits, 24);
    drop(engine);

    // sweep 3: sharded view of the same cache — hits + skips
    let engine = det_mock_engine(
        EngineConfig { shard: Some(Shard::parse("0/4").unwrap()), ..base.clone() },
        Arc::new(AtomicUsize::new(0)),
    );
    let sharded = engine.run(shared_job_list());
    assert!(sharded.skipped > 0, "a 4-way shard must decline foreign keys");
    drop(engine);

    // sweep 4: cancel right after submit — in-flight jobs finish, the
    // queued remainder is cancelled
    let engine = det_mock_engine(
        EngineConfig { cache_dir: Some(dir_cancel.clone()), ..base.clone() },
        Arc::new(AtomicUsize::new(0)),
    );
    let handle = engine.submit(shared_job_list());
    handle.cancel();
    let cancelled = handle.wait();
    assert!(cancelled.cancelled > 0, "cancel must unqueue pending jobs");
    drop(engine);

    assert_eq!(bus.dropped(), 0, "nothing may be dropped at this capacity");
    drop(base);
    drop(bus);
    let events: Vec<Envelope> = stream
        .map(|e| Envelope::parse(&e.line()).expect("published envelopes must re-parse"))
        .collect();
    let sweeps = split_sweeps(&events);
    assert_eq!(sweeps.len(), 4, "one segment per sweep");
    assert_segment_matches(sweeps[0], &fresh, "fresh");
    assert_segment_matches(sweeps[1], &resumed, "resumed");
    assert_segment_matches(sweeps[2], &sharded, "sharded");
    assert_segment_matches(sweeps[3], &cancelled, "cancelled");

    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir_cancel);
}

// ------------------------------------------------ crash-injected drive

/// Child-process entrypoint for the driven test below: drains the
/// shared sweep as one shard, streaming its engine's events to a JSONL
/// file (the same plumbing `repro exp --progress jsonl:PATH` uses).
/// With `UMUP_EVENTS_CRASH_ONCE=<path>` set and that path absent, the
/// child exits(3) after its drain is persisted and its event file is
/// flushed — the driver must restart it and the restarted attempt
/// resolves everything from the cache.
#[test]
fn events_child_entry() {
    if std::env::var("UMUP_EVENTS_ROLE").as_deref() != Ok("drain") {
        return;
    }
    let dir = PathBuf::from(std::env::var("UMUP_EVENTS_CACHE").expect("child cache dir"));
    let shard = Shard::parse(&std::env::var("UMUP_EVENTS_SPEC").expect("child shard spec"))
        .expect("valid shard spec");
    let path = std::env::var("UMUP_EVENTS_FILE").expect("child event file");
    let bus = EventBus::new().with_source(shard.index);
    let stream = bus.subscribe(4096);
    // append mode: a restarted attempt continues the same file
    let mut sink = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .expect("opening child event file");
    let writer = std::thread::spawn(move || {
        for e in stream {
            if writeln!(sink, "{}", e.line()).is_err() {
                break;
            }
        }
        let _ = sink.flush();
    });
    let engine = det_mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(dir),
            resume: true,
            shard: Some(shard),
            events: Some(bus.clone()),
            ..EngineConfig::default()
        },
        Arc::new(AtomicUsize::new(0)),
    );
    let report = engine.run(shared_job_list());
    assert_eq!(report.failed, 0, "mock jobs never fail");
    // flush the full event stream before (possibly) crashing, so the
    // injected failure tests the driver's restart accounting, not
    // torn-line recovery (drive.rs covers stale-lock reclaim)
    drop(engine);
    drop(bus);
    let _ = writer.join();
    if let Ok(marker) = std::env::var("UMUP_EVENTS_CRASH_ONCE") {
        if !Path::new(&marker).exists() {
            std::fs::write(&marker, "crashed once\n").expect("writing crash marker");
            std::process::exit(3);
        }
    }
}

/// The acceptance test: a crash-injected 4-shard drive with child
/// event streaming yields one merged, parseable JSONL stream whose
/// per-shard `job_done` counters exactly partition each attempt's
/// sweep total, whose executed keys are exactly the final cache
/// contents, and whose driver lifecycle events account for the
/// restart.
#[test]
fn driven_crash_injected_sweep_streams_a_partitioned_merged_log() {
    let exe = std::env::current_exe().unwrap();
    let dir = tmp_dir("drive");
    std::fs::create_dir_all(&dir).unwrap();
    let files: Vec<PathBuf> = (0..4).map(|i| dir.join(format!("events.{i}.jsonl"))).collect();
    let crash_marker = dir.join("crash-once.flag");
    let bus = EventBus::new();
    let stream = bus.subscribe(8192);
    let cfg = DriveConfig {
        shards: 4,
        cache_dir: dir.clone(),
        max_restarts_per_shard: 2,
        poll_interval: Duration::from_millis(25),
        progress: false,
        events: Some(bus.clone()),
        child_event_files: files.clone(),
        ..DriveConfig::default()
    };
    let report = drive(&cfg, |shard| {
        let mut cmd = Command::new(&exe);
        cmd.args(["events_child_entry", "--exact", "--nocapture", "--test-threads", "1"])
            .env("UMUP_EVENTS_ROLE", "drain")
            .env("UMUP_EVENTS_CACHE", &dir)
            .env("UMUP_EVENTS_SPEC", shard.to_string())
            .env("UMUP_EVENTS_FILE", &files[shard.index])
            .env("UMUP_CACHE_TS", "1700000000")
            .stdout(Stdio::null());
        if shard.index == 1 {
            cmd.env("UMUP_EVENTS_CRASH_ONCE", &crash_marker);
        }
        cmd
    })
    .expect("drive must succeed");
    assert_eq!(report.restarts, 1, "exactly the crashed shard restarts");
    assert_eq!(bus.dropped(), 0, "nothing may be dropped at this capacity");
    drop(cfg);
    drop(bus);

    let lines: Vec<String> = stream.map(|e| e.line()).collect();
    let n_jobs = shared_job_list().len();
    let mut per_shard: Vec<Vec<Envelope>> = vec![Vec::new(); 4];
    let mut driver_events: Vec<Envelope> = Vec::new();
    for line in &lines {
        let e = Envelope::parse(line)
            .unwrap_or_else(|err| panic!("unparseable event line {line:?}: {err:#}"));
        match &e.event {
            // driver-origin lifecycle/progress events (their `shard`
            // field names the subject, not the source)
            Event::ShardSpawned { .. }
            | Event::ShardExit { .. }
            | Event::ShardRestarted { .. }
            | Event::Snapshot { .. } => driver_events.push(e),
            _ => {
                let s = e.shard.expect("child events must carry their shard tag");
                per_shard[s].push(e);
            }
        }
    }

    // per shard: the last attempt's sweep partitions exactly; shard 1
    // ran twice (crash + restart), the others once
    let mut executed_keys: BTreeSet<String> = BTreeSet::new();
    for (shard, events) in per_shard.iter().enumerate() {
        let attempts = split_sweeps(events);
        let expected = if shard == 1 { 2 } else { 1 };
        assert_eq!(attempts.len(), expected, "shard {shard} attempts");
        for segment in &attempts {
            let total = match &segment[0].event {
                Event::SweepStarted { total, .. } => *total,
                _ => unreachable!("segments open with sweep_started"),
            };
            assert_eq!(total, n_jobs, "shard {shard}: every child sees the full sweep");
            let t = tally(segment);
            assert_eq!(
                t.executed + t.hits + t.dups + t.skips + t.cancelled,
                total,
                "shard {shard}: job_done statuses must partition the sweep: {t:?}"
            );
            let (counters, _) =
                t.finished.unwrap_or_else(|| panic!("shard {shard}: no sweep_finished"));
            assert_eq!(
                (counters.executed, counters.hits, counters.skips),
                (t.executed, t.hits, t.skips),
                "shard {shard}: finished counters disagree with the job_done tally"
            );
            for e in *segment {
                if let Event::JobDone { status: JobStatus::Executed, key, ok, .. } = &e.event {
                    assert!(*ok, "shard {shard}: mock jobs never fail");
                    executed_keys.insert(key.clone());
                }
            }
        }
        // the restarted attempt re-resolves everything without re-work
        if shard == 1 {
            let second = tally(attempts[1]);
            assert_eq!(second.executed, 0, "the restart must resume from the cache");
        }
    }

    // the executed-key union across all shards is exactly the cache
    let cache_keys: BTreeSet<String> =
        sorted_segment_lines(&dir).iter().map(|l| key_of_line(l)).collect();
    assert_eq!(cache_keys.len(), n_jobs);
    assert_eq!(executed_keys, cache_keys, "executed events must mirror the cache contents");
    assert_eq!(report.cache_entries, n_jobs);

    // driver lifecycle: 4 launches + 1 relaunch, one restart naming
    // shard 1, and a final clean exit for every shard
    let spawned = driver_events
        .iter()
        .filter(|e| matches!(e.event, Event::ShardSpawned { .. }))
        .count();
    assert_eq!(spawned, 5, "4 launches + 1 relaunch");
    let restarted: Vec<usize> = driver_events
        .iter()
        .filter_map(|e| match &e.event {
            Event::ShardRestarted { shard, .. } => Some(*shard),
            _ => None,
        })
        .collect();
    assert_eq!(restarted, vec![1], "exactly shard 1 is restarted");
    for shard in 0..4 {
        assert!(
            driver_events.iter().any(|e| matches!(
                &e.event,
                Event::ShardExit { shard: s, ok: true, .. } if *s == shard
            )),
            "shard {shard} must log a clean exit"
        );
    }
    assert!(
        driver_events.iter().any(|e| matches!(
            &e.event,
            Event::ShardExit { shard: 1, ok: false, .. }
        )),
        "the injected crash must be logged"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------- wire

fn repro_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_repro"))
}

fn spawn_listen_worker() -> (Child, String) {
    let mut cmd = Command::new(repro_exe());
    cmd.arg("worker").arg("--mock").arg("--listen").arg("127.0.0.1:0");
    cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::null());
    let mut child = cmd.spawn().expect("spawning listen worker");
    let stdout = child.stdout.take().expect("worker stdout is piped");
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).expect("reading the listen announcement");
    let addr = line
        .strip_prefix("listening ")
        .unwrap_or_else(|| panic!("unexpected worker announcement {line:?}"))
        .trim()
        .to_string();
    (child, addr)
}

fn ctl_json(addr: &str, verb: &str, extra: &[&str]) -> Json {
    let out = Command::new(repro_exe())
        .arg("ctl")
        .arg(verb)
        .args(extra)
        .arg("--addr")
        .arg(addr)
        .output()
        .expect("running repro ctl");
    assert!(
        out.status.success(),
        "ctl {verb} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    Json::parse(String::from_utf8_lossy(&out.stdout).trim()).expect("ctl output is JSON")
}

/// The wire acceptance test: a live `repro serve` daemon re-serves its
/// engine's event stream through the `events` verb — a raw client gets
/// every frame tagged with its request id and sees the submitted
/// sweep's partition, while `repro ctl watch` tails the same stream as
/// plain JSONL on stdout.
#[test]
fn serve_events_verb_and_ctl_watch_tail_the_live_stream() {
    std::env::set_var("UMUP_CACHE_TS", "1700000000");
    let cache = tmp_dir("serve-cache");
    let (mut worker, worker_addr) = spawn_listen_worker();
    let mut daemon = Command::new(repro_exe())
        .arg("serve")
        .arg("--addr")
        .arg("127.0.0.1:0")
        .arg("--workers")
        .arg(&worker_addr)
        .arg("--cache-dir")
        .arg(&cache)
        .arg("--resume")
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning repro serve");
    let stdout = daemon.stdout.take().expect("serve stdout is piped");
    let mut reader = BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("reading serve stdout");
        assert_ne!(n, 0, "serve exited before announcing its endpoint");
        if let Some(a) = line.strip_prefix("serving ") {
            break a.trim().to_string();
        }
    };

    // raw events client: hello, then the stream-mode `events` request
    let mut sock = TcpStream::connect(&addr).expect("connecting the events client");
    sock.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut sock_reader = BufReader::new(sock.try_clone().unwrap());
    let hello = wire::read_frame(&mut sock_reader).unwrap().expect("serve hello");
    wire::check_serve_hello(&hello).unwrap();
    wire::write_frame(&mut sock, &wire::rpc_request_line(7, "events", &Json::Obj(BTreeMap::new())))
        .unwrap();

    // ... and the CLI tail of the same stream
    let mut watch = Command::new(repro_exe())
        .arg("ctl")
        .arg("watch")
        .arg("--addr")
        .arg(&addr)
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning repro ctl watch");
    // both subscriptions must land in the engine owner loop before the
    // submit below, or the earliest events are (legitimately) missed
    std::thread::sleep(Duration::from_millis(500));

    let jobs = shared_job_list();
    let n_jobs = jobs.len();
    let jobs_path = tmp_dir("serve-jobs").with_extension("jsonl");
    let mut text = String::new();
    for job in &jobs {
        text.push_str(&wire::encode_job(&job.key(), job));
        text.push('\n');
    }
    std::fs::write(&jobs_path, text).unwrap();
    let r = ctl_json(&addr, "submit", &["--jobs", jobs_path.to_str().unwrap()]);
    assert_eq!(r.get("total").unwrap().as_usize().unwrap(), n_jobs);

    // raw client: every frame is an Ok reply tagged with *our* request
    // id, carrying one envelope; collect until the sweep finishes
    let mut events: Vec<Envelope> = Vec::new();
    loop {
        let frame = wire::read_frame(&mut sock_reader)
            .expect("reading an event frame")
            .expect("stream must outlive the sweep");
        let env = match wire::decode_rpc_reply(&frame).expect("event frames are rpc replies") {
            wire::RpcReply::Ok { id, result } => {
                assert_eq!(id, 7, "event frames must carry the subscribing request's id");
                Envelope::parse(&result.dump()).expect("frame payload must be an envelope")
            }
            wire::RpcReply::Err { error, .. } => panic!("unexpected error frame: {error}"),
        };
        let finished = matches!(env.event, Event::SweepFinished { .. });
        events.push(env);
        if finished {
            break;
        }
    }
    let sweeps = split_sweeps(&events);
    assert_eq!(sweeps.len(), 1, "one submission, one sweep segment");
    let t = tally(sweeps[0]);
    assert_eq!(t.queued, n_jobs);
    assert_eq!(
        t.executed + t.hits + t.dups + t.skips + t.cancelled,
        n_jobs,
        "the served stream must partition the sweep: {t:?}"
    );
    assert_eq!(t.executed, n_jobs, "a fresh cache executes everything");
    drop(sock);
    drop(sock_reader);

    // the CLI tail prints the same stream as bare JSONL: read until it
    // has echoed the sweep's completion
    let watch_out = watch.stdout.take().expect("watch stdout is piped");
    let mut watch_reader = BufReader::new(watch_out);
    let mut watch_done = 0usize;
    let mut watch_finished = false;
    for _ in 0..10_000 {
        let mut line = String::new();
        let n = watch_reader.read_line(&mut line).expect("reading watch output");
        assert_ne!(n, 0, "watch ended before the sweep finished");
        let env = Envelope::parse(line.trim()).expect("watch lines must be envelopes");
        match env.event {
            Event::JobDone { .. } => watch_done += 1,
            Event::SweepFinished { .. } => {
                watch_finished = true;
                break;
            }
            _ => {}
        }
    }
    assert!(watch_finished, "watch never saw the sweep finish");
    assert_eq!(watch_done, n_jobs, "watch must tail every terminal job event");
    let _ = watch.kill();
    let _ = watch.wait();

    let r = ctl_json(&addr, "shutdown", &[]);
    assert!(r.get("shutdown").unwrap().as_bool().unwrap());
    let exit = daemon.wait().expect("waiting for serve");
    assert!(exit.success(), "serve must exit cleanly after shutdown");

    let _ = worker.kill();
    let _ = worker.wait();
    let _ = std::fs::remove_file(&jobs_path);
    let _ = std::fs::remove_dir_all(&cache);
}
