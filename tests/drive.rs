//! Integration harness for the shard driver (`engine::driver::drive`,
//! the library behind `repro drive --shards n`), reusing the PR 2
//! self-re-exec pattern: this test binary is its own shard child.
//!
//! The acceptance contract: a driven 4-shard drain over a small grid
//! completes with merged cache content **byte-identical** to the
//! single-process run — including when one shard crashes mid-drive and
//! is restarted by the driver (its stale segment lock is reclaimed, its
//! persisted runs are resumed).  A shard that keeps crashing exhausts
//! its restart budget and fails the drive with the surviving children
//! torn down.
//!
//! Everything runs on the mock backend (`Engine::with_backend` +
//! `MockBackend`) with `UMUP_CACHE_TS` pinned, so no XLA artifacts are
//! needed and cache lines are byte-for-byte reproducible.

mod common;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::atomic::AtomicUsize;
use std::sync::Arc;
use std::time::Duration;

use common::{det_mock_engine, key_of_line, shared_job_list, sorted_segment_lines};
use umup::engine::driver::{drive, DriveConfig};
use umup::engine::{EngineConfig, Shard};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("umup-drive-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

// --------------------------------------------------- child process main

/// Child-process entrypoint.  Inert as a normal test; when re-executed
/// by the driver tests (selected via `UMUP_DRIVE_ROLE`) it acts as one
/// shard process:
///
/// * `drain` — drain the shared sweep into `UMUP_DRIVE_CACHE` as shard
///   `UMUP_DRIVE_SPEC` (unsharded without it), writing a marker file the
///   parent asserts on.  With `UMUP_DRIVE_CRASH_ONCE=<path>` set and
///   that path absent, it exits(3) *after* draining but before
///   releasing its segment lock — simulating a crash whose restart must
///   reclaim the stale lock and resume.
/// * `crash` — exit(3) immediately (restart-budget exhaustion test).
#[test]
fn drive_child_entry() {
    match std::env::var("UMUP_DRIVE_ROLE").as_deref() {
        Ok("drain") => {}
        Ok("crash") => std::process::exit(3),
        _ => return,
    }
    let dir = PathBuf::from(std::env::var("UMUP_DRIVE_CACHE").expect("child cache dir"));
    let shard = match std::env::var("UMUP_DRIVE_SPEC") {
        Ok(s) => Some(Shard::parse(&s).expect("child shard spec")),
        Err(_) => None,
    };
    let counter = Arc::new(AtomicUsize::new(0));
    let engine = det_mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(dir.clone()),
            resume: true,
            shard,
            ..EngineConfig::default()
        },
        Arc::clone(&counter),
    );
    let jobs = shared_job_list();
    let n_jobs = jobs.len();
    let report = engine.run(jobs);
    assert_eq!(report.outcomes.len(), n_jobs);
    assert_eq!(report.failed, 0, "mock jobs never fail");
    for o in &report.outcomes {
        assert!(
            o.outcome.is_ok() || o.skipped,
            "child outcome must be ok or an explicit shard skip: {:?}",
            o.outcome.as_ref().err()
        );
    }
    // simulated crash: results are already persisted (workers flush
    // before reporting), but the process dies without dropping the
    // engine — leaving a stale segment lock for the restart to reclaim
    if let Ok(marker) = std::env::var("UMUP_DRIVE_CRASH_ONCE") {
        if !Path::new(&marker).exists() {
            std::fs::write(&marker, "crashed once\n").expect("writing crash marker");
            std::process::exit(3);
        }
    }
    drop(engine); // release the segment lock before the parent inspects
    let tag = shard.map_or("single".to_string(), |s| format!("{}-{}", s.index, s.count));
    std::fs::write(
        dir.join(format!("child-{tag}.ok")),
        format!("{} {}\n", report.executed, report.skipped),
    )
    .expect("writing child marker");
}

fn child_cmd(exe: &Path, dir: &Path, shard: Option<Shard>) -> Command {
    let mut cmd = Command::new(exe);
    cmd.args(["drive_child_entry", "--exact", "--nocapture", "--test-threads", "1"])
        .env("UMUP_DRIVE_ROLE", "drain")
        .env("UMUP_DRIVE_CACHE", dir)
        .env("UMUP_CACHE_TS", "1700000000");
    if let Some(s) = shard {
        cmd.env("UMUP_DRIVE_SPEC", s.to_string());
    }
    cmd
}

// ---------------------------------------------------------------- tests

/// The acceptance test: `drive` over 4 shard processes — one of which
/// crashes once and is restarted — produces merged cache content
/// byte-identical to the single-process run, with zero duplicate keys.
#[test]
fn driven_four_shards_with_one_crash_match_single_process() {
    let exe = std::env::current_exe().unwrap();
    let single = tmp_dir("single");
    let sharded = tmp_dir("sharded");

    // reference: one unsharded child process
    let status = child_cmd(&exe, &single, None)
        .stdout(std::process::Stdio::null())
        .spawn()
        .unwrap()
        .wait()
        .unwrap();
    assert!(status.success(), "single-process reference child failed");
    assert!(single.join("child-single.ok").exists(), "reference child never ran");

    // driven topology: 4 shards, shard 1 crashes on its first attempt
    std::fs::create_dir_all(&sharded).unwrap();
    let crash_marker = sharded.join("crash-once.flag");
    let cfg = DriveConfig {
        shards: 4,
        cache_dir: sharded.clone(),
        max_restarts_per_shard: 2,
        poll_interval: Duration::from_millis(25),
        progress: false,
        ..DriveConfig::default()
    };
    let report = drive(&cfg, |shard| {
        let mut cmd = child_cmd(&exe, &sharded, Some(shard));
        if shard.index == 1 {
            cmd.env("UMUP_DRIVE_CRASH_ONCE", &crash_marker);
        }
        cmd
    })
    .expect("drive must succeed");

    assert_eq!(report.restarts, 1, "exactly the crashed shard restarts");
    assert_eq!(report.shard_outcomes.len(), 4);
    for so in &report.shard_outcomes {
        assert!(so.success, "shard {} did not finish", so.shard);
        let expected_attempts = if so.shard == 1 { 2 } else { 1 };
        assert_eq!(so.attempts, expected_attempts, "shard {}", so.shard);
    }
    for i in 0..4 {
        assert!(
            sharded.join(format!("child-{i}-4.ok")).exists(),
            "shard {i} child never completed a full drain"
        );
    }

    // merged shard segments == the single-process segment, byte-for-byte
    // modulo ordering (UMUP_CACHE_TS pins the timestamp field)
    let jobs = shared_job_list();
    let single_lines = sorted_segment_lines(&single);
    let sharded_lines = sorted_segment_lines(&sharded);
    assert_eq!(single_lines.len(), jobs.len());
    assert_eq!(
        sharded_lines, single_lines,
        "driven merged cache must equal the unsharded run"
    );
    let keys: BTreeSet<String> = sharded_lines.iter().map(|l| key_of_line(l)).collect();
    assert_eq!(keys.len(), jobs.len(), "duplicate run keys across segments");
    assert_eq!(report.cache_entries, jobs.len());

    let _ = std::fs::remove_dir_all(&single);
    let _ = std::fs::remove_dir_all(&sharded);
}

/// A shard that crashes on every attempt exhausts its restart budget:
/// the drive fails, naming the shard, and tears the topology down.
#[test]
fn drive_fails_once_restart_budget_is_exhausted() {
    let exe = std::env::current_exe().unwrap();
    let dir = tmp_dir("budget");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = DriveConfig {
        shards: 2,
        cache_dir: dir.clone(),
        max_restarts_per_shard: 1,
        poll_interval: Duration::from_millis(10),
        progress: false,
        ..DriveConfig::default()
    };
    let err = drive(&cfg, |shard| {
        let mut cmd = Command::new(&exe);
        cmd.args(["drive_child_entry", "--exact", "--nocapture", "--test-threads", "1"])
            .env("UMUP_DRIVE_CACHE", &dir)
            .env("UMUP_CACHE_TS", "1700000000");
        if shard.index == 0 {
            // shard 0 drains normally (it may finish or be torn down)
            cmd.env("UMUP_DRIVE_ROLE", "drain")
                .env("UMUP_DRIVE_SPEC", shard.to_string());
        } else {
            cmd.env("UMUP_DRIVE_ROLE", "crash");
        }
        cmd
    })
    .expect_err("a permanently-crashing shard must fail the drive");
    let msg = format!("{err:#}");
    assert!(msg.contains("shard 1/2"), "error must name the failing shard: {msg}");
    assert!(msg.contains("restart budget"), "{msg}");
    let _ = std::fs::remove_dir_all(&dir);
}
