//! Engine unit-integration tests: cache-key stability, cache hit/miss +
//! resume-from-disk roundtrips, in-batch deduplication, failure
//! isolation under concurrency, and the handle-based submission API
//! (streaming outcomes, cancellation, priorities, affinity scheduling).
//!
//! These run without XLA artifacts: a [`MockBackend`] swaps the
//! session-backed executor for a closure, so the queueing/caching/
//! outcome machinery is exercised on any machine (including CI runners
//! with no compiled artifact tree).

mod common;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use common::{cfg, dummy_corpus, dummy_manifest};
use umup::data::{Corpus, CorpusConfig};
use umup::engine::{
    run_key, Engine, EngineConfig, EngineJob, LruPool, MockBackend, RunCache, SubmitOptions,
    SweepJob,
};
use umup::train::RunRecord;

fn fake_record(label: &str, loss: f64) -> RunRecord {
    RunRecord {
        label: label.to_string(),
        train_curve: vec![(1, loss + 1.0), (2, loss)],
        valid_curve: vec![(2, loss)],
        final_valid_loss: loss,
        rms_curves: BTreeMap::new(),
        final_rms: vec![("w.head".to_string(), 1.0)],
        diverged: false,
        wall_seconds: 0.01,
    }
}

/// A mock engine: each "run" sleeps briefly and returns a loss derived
/// from the config's eta; labels starting with "fail" error out.
/// `counter` counts actual executions (not cache/dedup resolutions).
fn mock_engine(engine_cfg: EngineConfig, counter: Arc<AtomicUsize>) -> Engine {
    let backend = MockBackend::new(move |_worker| {
        let counter = Arc::clone(&counter);
        Box::new(move |job: &EngineJob| -> anyhow::Result<RunRecord> {
            std::thread::sleep(std::time::Duration::from_millis(5));
            counter.fetch_add(1, Ordering::SeqCst);
            if job.config.label.starts_with("fail") {
                anyhow::bail!("injected failure for {}", job.config.label);
            }
            if job.config.label.starts_with("panic") {
                panic!("injected panic for {}", job.config.label);
            }
            Ok(fake_record(&job.config.label, 2.0 + job.config.hp.eta))
        })
    });
    Engine::with_backend(engine_cfg, Arc::new(backend)).unwrap()
}

// ---------------------------------------------------------------- keys

#[test]
fn cache_key_is_stable_across_field_set_order_and_ignores_label() {
    let co = dummy_corpus();
    let mut a = cfg("figure-one-lr00", 0.5, 64);
    a.hp.set("alpha_attn", 2.0);
    a.hp.set("alpha_res", 0.25);
    a.rms_sites = vec!["w.head".into()];
    let mut b = cfg("figure-five-baseline", 0.5, 64);
    b.rms_sites = vec!["w.head".into()];
    b.hp.set("alpha_res", 0.25); // same fields, different set order
    b.hp.set("alpha_attn", 2.0);
    // labels differ, content is identical -> same address
    assert_eq!(run_key("w64_d4_b16", &co, &a), run_key("w64_d4_b16", &co, &b));
    // the canonical dump itself is deterministic
    assert_eq!(a.canonical_json().dump(), b.canonical_json().dump());
    // every content field perturbs the key
    let mut c = b.clone();
    c.seed += 1;
    assert_ne!(run_key("w64_d4_b16", &co, &b), run_key("w64_d4_b16", &co, &c));
    let mut d = b.clone();
    d.hp.eta = 0.25;
    assert_ne!(run_key("w64_d4_b16", &co, &b), run_key("w64_d4_b16", &co, &d));
    let mut e = b.clone();
    e.lr_tweaks = vec![("emb".into(), 4.0)];
    assert_ne!(run_key("w64_d4_b16", &co, &b), run_key("w64_d4_b16", &co, &e));
    // the manifest is part of the address
    assert_ne!(run_key("w64_d4_b16", &co, &b), run_key("w128_d4_b16", &co, &b));
    // and so is the corpus: a quick-mode corpus must never satisfy a
    // full-corpus run of the same config
    let big = Arc::new(Corpus {
        config: CorpusConfig { vocab: 64, n_tokens: 2_000_000, ..Default::default() },
        tokens: vec![],
        n_train: 0,
    });
    assert_ne!(run_key("w64_d4_b16", &co, &b), run_key("w64_d4_b16", &big, &b));
}

// --------------------------------------------------------------- cache

#[test]
fn run_cache_roundtrips_and_resumes_from_disk() {
    let dir = std::env::temp_dir().join(format!("umup-cache-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let key = run_key("m", &dummy_corpus(), &cfg("x", 0.5, 8));
    {
        let mut cache = RunCache::open(&dir, false).unwrap();
        assert!(cache.is_empty());
        cache.put(&key, "m", &fake_record("x", 2.5)).unwrap();
        assert_eq!(cache.len(), 1);
    }
    // resume loads the persisted record faithfully (lazily: the key is
    // indexed at open, the record parses on this first get)
    let mut cache = RunCache::open(&dir, true).unwrap();
    let rec = cache.get(&key).expect("resumed entry");
    assert_eq!(rec.final_valid_loss, 2.5);
    assert_eq!(rec.train_curve, vec![(1, 3.5), (2, 2.5)]);
    assert_eq!(rec.final_rms, vec![("w.head".to_string(), 1.0)]);
    assert!(cache.get("0000000000000000").is_none());
    drop(cache);
    // without resume, the file is a fresh recording
    let cache = RunCache::open(&dir, false).unwrap();
    assert!(cache.is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn engine_dedupes_within_a_batch_and_hits_cache_across_batches() {
    let counter = Arc::new(AtomicUsize::new(0));
    let engine = mock_engine(EngineConfig { workers: 2, ..EngineConfig::default() },
        Arc::clone(&counter));
    let man = dummy_manifest("m");
    let corpus = dummy_corpus();
    // 4 jobs, but only 2 distinct contents (labels differ on purpose)
    let jobs = vec![
        SweepJob { config: cfg("a0", 0.5, 8), tag: vec![] },
        SweepJob { config: cfg("a1-same-as-a0", 0.5, 8), tag: vec![] },
        SweepJob { config: cfg("b0", 1.0, 8), tag: vec![] },
        SweepJob { config: cfg("b1-same-as-b0", 1.0, 8), tag: vec![] },
    ];
    let res = engine.run_sweep(&man, &corpus, &jobs).unwrap();
    assert_eq!(res.len(), 4);
    assert_eq!(counter.load(Ordering::SeqCst), 2, "duplicates must not execute");
    // results keep job order and job labels
    assert_eq!(res[1].record.final_valid_loss, res[0].record.final_valid_loss);
    assert_eq!(res[1].record.label, "a1-same-as-a0");
    assert!(res[2].record.final_valid_loss > res[0].record.final_valid_loss);
    // second batch: all four resolve from the in-memory cache
    engine.run_sweep(&man, &corpus, &jobs).unwrap();
    assert_eq!(counter.load(Ordering::SeqCst), 2);
    let s = engine.stats();
    assert_eq!(s.executed, 2);
    assert_eq!(s.deduped, 2);
    assert_eq!(s.cache_hits, 4);
}

#[test]
fn engine_resumes_a_sweep_from_a_populated_cache_dir() {
    let dir = std::env::temp_dir().join(format!("umup-engine-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let man = dummy_manifest("m");
    let corpus = dummy_corpus();
    let jobs = vec![
        SweepJob { config: cfg("a", 0.5, 8), tag: vec![] },
        SweepJob { config: cfg("b", 1.0, 8), tag: vec![] },
    ];
    let c1 = Arc::new(AtomicUsize::new(0));
    let engine = mock_engine(
        EngineConfig { workers: 2, cache_dir: Some(dir.clone()), ..EngineConfig::default() },
        Arc::clone(&c1),
    );
    let first = engine.run_sweep(&man, &corpus, &jobs).unwrap();
    assert_eq!(c1.load(Ordering::SeqCst), 2);
    drop(engine);
    // "process restart": a fresh engine with --resume skips everything
    let c2 = Arc::new(AtomicUsize::new(0));
    let engine = mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(dir.clone()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::clone(&c2),
    );
    let second = engine.run_sweep(&man, &corpus, &jobs).unwrap();
    assert_eq!(c2.load(Ordering::SeqCst), 0, "resumed sweep must skip completed jobs");
    for (x, y) in first.iter().zip(&second) {
        assert_eq!(x.record.final_valid_loss, y.record.final_valid_loss);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------ failures

#[test]
fn failing_job_is_isolated_and_the_rest_complete_concurrently() {
    let counter = Arc::new(AtomicUsize::new(0));
    let engine = mock_engine(EngineConfig { workers: 3, ..EngineConfig::default() },
        Arc::clone(&counter));
    let man = dummy_manifest("m");
    let corpus = dummy_corpus();
    let mut jobs: Vec<EngineJob> = (0..7)
        .map(|i| {
            EngineJob::new(
                Arc::clone(&man),
                dummy_corpus(),
                cfg(&format!("ok-{i}"), 0.25 * (i + 1) as f64, 8),
                vec![],
            )
        })
        .collect();
    jobs.insert(
        3,
        EngineJob::new(Arc::clone(&man), Arc::clone(&corpus), cfg("fail-me", 9.0, 8), vec![]),
    );
    let report = engine.run(jobs);
    assert_eq!(report.outcomes.len(), 8);
    assert_eq!(report.failed, 1);
    assert_eq!(report.completed, 7);
    assert_eq!(report.executed, 8, "every job ran despite the failure");
    assert_eq!(counter.load(Ordering::SeqCst), 8);
    for (i, o) in report.outcomes.iter().enumerate() {
        if i == 3 {
            let err = o.outcome.as_ref().unwrap_err();
            assert!(err.contains("injected failure"), "{err}");
        } else {
            assert!(o.outcome.is_ok(), "job {i} should have completed");
        }
    }
    // the strict view surfaces the error without hiding the attempt
    // (fresh etas: these must not alias earlier runs in the cache)
    let jobs2 = vec![
        SweepJob { config: cfg("fine", 0.3, 8), tag: vec![] },
        SweepJob { config: cfg("fail-again", 0.9, 8), tag: vec![] },
    ];
    let err = engine.run_sweep(&man, &corpus, &jobs2).unwrap_err().to_string();
    assert!(err.contains("fail-again"), "{err}");
}

#[test]
fn panicking_job_does_not_kill_the_worker() {
    let counter = Arc::new(AtomicUsize::new(0));
    // workers: 1 — if the panic killed the worker, every later job
    // (and the next batch) would fail instead of running
    let engine = mock_engine(EngineConfig { workers: 1, ..EngineConfig::default() },
        Arc::clone(&counter));
    let man = dummy_manifest("m");
    let corpus = dummy_corpus();
    let jobs = vec![
        SweepJob { config: cfg("ok-first", 0.25, 8), tag: vec![] },
        SweepJob { config: cfg("panic-now", 0.5, 8), tag: vec![] },
        SweepJob { config: cfg("ok-after", 0.75, 8), tag: vec![] },
    ];
    let report = engine.run(
        jobs.iter()
            .map(|j| {
                EngineJob::new(
                    Arc::clone(&man),
                    Arc::clone(&corpus),
                    j.config.clone(),
                    j.tag.clone(),
                )
            })
            .collect(),
    );
    assert_eq!(report.completed, 2);
    assert_eq!(report.failed, 1);
    let err = report.outcomes[1].outcome.as_ref().unwrap_err();
    assert!(err.contains("panicked") && err.contains("injected panic"), "{err}");
    assert!(report.outcomes[2].outcome.is_ok(), "worker must survive the panic");
    // and the same engine still serves the next batch
    let again = engine
        .run_sweep(&man, &corpus, &[SweepJob { config: cfg("ok-later", 1.25, 8), tag: vec![] }])
        .unwrap();
    assert_eq!(again.len(), 1);
}

// ------------------------------------------------------------- handles

/// Outcomes stream in completion order through `recv`, duplicates
/// resolve right after their primary, and the stream terminates with
/// `None` exactly once per job.
#[test]
fn handle_streams_outcomes_as_they_complete() {
    let counter = Arc::new(AtomicUsize::new(0));
    let engine = mock_engine(EngineConfig { workers: 1, ..EngineConfig::default() },
        Arc::clone(&counter));
    let man = dummy_manifest("m");
    let corpus = dummy_corpus();
    let jobs: Vec<EngineJob> = [("a", 0.25), ("a-dup", 0.25), ("b", 0.5)]
        .iter()
        .map(|&(label, eta)| {
            EngineJob::new(Arc::clone(&man), Arc::clone(&corpus), cfg(label, eta, 8), vec![])
        })
        .collect();
    let mut handle = engine.submit(jobs);
    assert_eq!(handle.len(), 3);
    let mut seen = Vec::new();
    while let Some(o) = handle.recv() {
        seen.push((o.idx, o.cached, o.outcome.is_ok()));
    }
    assert!(handle.is_done());
    assert_eq!(handle.remaining(), 0);
    // one worker, FIFO within one manifest: primary a (idx 0) first,
    // its duplicate resolves immediately after from the same record,
    // then b
    assert_eq!(seen, vec![(0, false, true), (1, true, true), (2, false, true)]);
    assert_eq!(counter.load(Ordering::SeqCst), 2, "duplicate must not execute");
    // a drained handle keeps returning None
    assert!(handle.recv().is_none() && handle.try_recv().is_none());
}

/// The affinity satellite: a 2-worker engine fed interleaved jobs from
/// 2 manifests must end with per-worker session-pool hit rates above
/// the FIFO baseline.  With pool capacity 1, FIFO hands every worker an
/// alternating m1/m2 stream — each worker's LruPool thrashes, ~24
/// compiles for 24 jobs.  The affinity scheduler keeps each worker on
/// one warm manifest and crosses over only when idle, so the whole
/// sweep costs at most workers x manifests = 4 compiles.
#[test]
fn affinity_scheduler_beats_fifo_for_interleaved_manifests() {
    let compiles = Arc::new(AtomicUsize::new(0));
    let compiles_in_factory = Arc::clone(&compiles);
    // mirror the production executor: a real LruPool per worker, cap 1
    let engine = Engine::with_backend(
        EngineConfig { workers: 2, max_sessions_per_worker: 1, ..EngineConfig::default() },
        Arc::new(MockBackend::new(move |_worker| {
            let compiles = Arc::clone(&compiles_in_factory);
            let mut pool: LruPool<String> = LruPool::new(1);
            Box::new(move |job: &EngineJob| -> anyhow::Result<RunRecord> {
                pool.get_or_create(&job.manifest.name, || {
                    compiles.fetch_add(1, Ordering::SeqCst);
                    Ok(job.manifest.name.clone())
                })?;
                std::thread::sleep(std::time::Duration::from_millis(3));
                Ok(fake_record(&job.config.label, 2.0 + job.config.hp.eta))
            })
        })),
    )
    .unwrap();

    let corpus = dummy_corpus();
    let (m1, m2) = (dummy_manifest("m1"), dummy_manifest("m2"));
    // strictly interleaved: m1, m2, m1, m2, ... with distinct etas
    let jobs: Vec<EngineJob> = (0..24)
        .map(|i| {
            EngineJob::new(
                Arc::clone(if i % 2 == 0 { &m1 } else { &m2 }),
                Arc::clone(&corpus),
                cfg(&format!("j{i}"), 0.0625 * (i + 1) as f64, 8),
                vec![],
            )
        })
        .collect();
    let report = engine.run(jobs);
    assert_eq!(report.completed, 24);
    assert_eq!(report.executed, 24);

    let compiled = compiles.load(Ordering::SeqCst);
    assert!(
        compiled <= 4,
        "affinity must bound compiles by workers x manifests, got {compiled} \
         (FIFO baseline for this workload is ~24)"
    );
    // the scheduler's warm model mirrors the executor's LruPool exactly
    // (same capacity, same MRU discipline), so its steal counter equals
    // the observed compile count, and hits account for the rest
    let s = engine.stats();
    assert_eq!(s.pool_steals, compiled);
    assert_eq!(s.pool_hits + s.pool_steals, 24);
    assert!(
        s.pool_hits >= 20,
        "per-worker hit rate must beat the FIFO baseline (~0): {} hits / 24",
        s.pool_hits
    );
}

/// Capability flags are load-bearing: a backend that advertises no
/// per-manifest warm state (`Capabilities::session_affinity == false`)
/// gets plain priority+FIFO dispatch — the scheduler keeps no warm
/// mirror and records no hits or steals, while the drain itself is
/// unaffected.
#[test]
fn no_affinity_capability_disables_warm_tracking() {
    let counter = Arc::new(AtomicUsize::new(0));
    let backend = MockBackend::new({
        let counter = Arc::clone(&counter);
        move |_worker| {
            let counter = Arc::clone(&counter);
            Box::new(move |job: &EngineJob| -> anyhow::Result<RunRecord> {
                counter.fetch_add(1, Ordering::SeqCst);
                Ok(fake_record(&job.config.label, 2.0 + job.config.hp.eta))
            })
        }
    })
    .without_affinity();
    let engine = Engine::with_backend(
        EngineConfig { workers: 2, max_sessions_per_worker: 1, ..EngineConfig::default() },
        Arc::new(backend),
    )
    .unwrap();
    let corpus = dummy_corpus();
    let (m1, m2) = (dummy_manifest("m1"), dummy_manifest("m2"));
    let jobs: Vec<EngineJob> = (0..16)
        .map(|i| {
            EngineJob::new(
                Arc::clone(if i % 2 == 0 { &m1 } else { &m2 }),
                Arc::clone(&corpus),
                cfg(&format!("na{i}"), 0.0625 * (i + 1) as f64, 8),
                vec![],
            )
        })
        .collect();
    let report = engine.run(jobs);
    assert_eq!(report.completed, 16);
    assert_eq!(counter.load(Ordering::SeqCst), 16);
    let s = engine.stats();
    assert_eq!(
        (s.pool_hits, s.pool_steals),
        (0, 0),
        "a no-affinity backend must not be charged for warmness"
    );
}

/// Cancellation satellite: a cancelled handle's pending jobs never
/// execute, the in-flight job completes, and the cache stays consistent
/// — a resumed engine re-runs exactly the cancelled jobs.
#[test]
fn cancelled_handle_skips_pending_jobs_and_cache_stays_consistent() {
    let dir = std::env::temp_dir().join(format!("umup-cancel-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let man = dummy_manifest("m");
    let corpus = dummy_corpus();
    let jobs = |manifest: &Arc<umup::runtime::Manifest>| -> Vec<EngineJob> {
        (0..8)
            .map(|i| {
                EngineJob::new(
                    Arc::clone(manifest),
                    dummy_corpus(),
                    cfg(&format!("c{i}"), 0.125 * (i + 1) as f64, 8),
                    vec![],
                )
            })
            .collect()
    };

    let c1 = Arc::new(AtomicUsize::new(0));
    // one slow worker: jobs take ~25ms, so cancellation lands while
    // most of the batch is still queued
    let engine = Engine::with_backend(
        EngineConfig { workers: 1, cache_dir: Some(dir.clone()), ..EngineConfig::default() },
        Arc::new(MockBackend::new({
            let c1 = Arc::clone(&c1);
            move |_worker| {
                let c1 = Arc::clone(&c1);
                Box::new(move |job: &EngineJob| -> anyhow::Result<RunRecord> {
                    std::thread::sleep(std::time::Duration::from_millis(25));
                    c1.fetch_add(1, Ordering::SeqCst);
                    Ok(fake_record(&job.config.label, 2.0 + job.config.hp.eta))
                })
            }
        })),
    )
    .unwrap();

    let mut handle = engine.submit(jobs(&man));
    let first = handle.recv().expect("first outcome");
    assert!(first.outcome.is_ok());
    handle.cancel();
    let report = handle.wait();
    assert_eq!(report.outcomes.len(), 8);
    // the first job plus whatever the single worker managed to start
    // before the cancel landed — never the whole batch (generous bound:
    // CI schedulers can stall this thread for a couple of job-lengths)
    let ran = c1.load(Ordering::SeqCst);
    assert!(ran <= 5, "cancel must stop the queue promptly, {ran} of 8 jobs ran");
    assert_eq!(report.executed, ran);
    assert_eq!(report.cancelled, 8 - ran);
    assert_eq!(report.completed, ran);
    for o in &report.outcomes {
        if o.cancelled {
            assert!(o.outcome.as_ref().unwrap_err().contains("cancelled"), "marked err");
            assert!(!o.skipped);
        }
    }
    // cache consistency: exactly the executed records are addressable
    assert_eq!(engine.cache_len(), ran);
    drop(engine);

    // a fresh engine resuming the same dir re-runs exactly the
    // cancelled jobs, completing the sweep
    let c2 = Arc::new(AtomicUsize::new(0));
    let engine = mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(dir.clone()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::clone(&c2),
    );
    let report = engine.run(jobs(&man));
    assert_eq!(report.completed, 8);
    assert_eq!(report.cache_hits, ran);
    assert_eq!(c2.load(Ordering::SeqCst), 8 - ran, "only cancelled jobs re-run");
    assert_eq!(engine.cache_len(), 8);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A higher-priority submission overtakes an earlier lower-priority
/// one: with one worker gated on the first job, the high-priority jobs
/// run before the rest of the first batch.
#[test]
fn higher_priority_submission_overtakes_queued_jobs() {
    let gate = Arc::new(AtomicBool::new(false));
    let order: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let engine = Engine::with_backend(
        EngineConfig { workers: 1, ..EngineConfig::default() },
        Arc::new(MockBackend::new({
            let gate = Arc::clone(&gate);
            let order = Arc::clone(&order);
            move |_worker| {
                let gate = Arc::clone(&gate);
                let order = Arc::clone(&order);
                Box::new(move |job: &EngineJob| -> anyhow::Result<RunRecord> {
                    order.lock().unwrap().push(job.config.label.clone());
                    if job.config.label.starts_with("gate") {
                        while !gate.load(Ordering::SeqCst) {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                    }
                    Ok(fake_record(&job.config.label, 2.0 + job.config.hp.eta))
                })
            }
        })),
    )
    .unwrap();
    let man = dummy_manifest("m");
    let corpus = dummy_corpus();
    let mk = |label: &str, eta: f64| {
        EngineJob::new(Arc::clone(&man), Arc::clone(&corpus), cfg(label, eta, 8), vec![])
    };
    // low-priority batch first; the worker blocks inside gate-a0 until
    // the high-priority batch is queued, making the race deterministic
    let low = engine.submit(vec![
        mk("gate-a0", 0.1),
        mk("a1", 0.2),
        mk("a2", 0.3),
        mk("a3", 0.4),
    ]);
    // ensure the worker is already inside gate-a0 (not still parked)
    // before the high-priority batch lands
    while order.lock().unwrap().is_empty() {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let high = engine.submit_with(
        vec![mk("b0", 0.6), mk("b1", 0.7)],
        SubmitOptions { priority: 5 },
    );
    gate.store(true, Ordering::SeqCst);
    let high_report = high.wait();
    let low_report = low.wait();
    assert_eq!(high_report.completed, 2);
    assert_eq!(low_report.completed, 4);
    let order = order.lock().unwrap().clone();
    assert_eq!(order[0], "gate-a0");
    assert_eq!(order[1], "b0", "high-priority jobs must overtake the queued batch: {order:?}");
    assert_eq!(order[2], "b1", "high-priority jobs must overtake the queued batch: {order:?}");
}

#[test]
fn multi_manifest_batches_drain_through_one_queue() {
    let counter = Arc::new(AtomicUsize::new(0));
    let engine = mock_engine(EngineConfig { workers: 2, ..EngineConfig::default() },
        Arc::clone(&counter));
    let corpus = dummy_corpus();
    let jobs: Vec<EngineJob> = ["w32", "w64", "w128"]
        .iter()
        .flat_map(|name| {
            let man = dummy_manifest(name);
            let corpus = Arc::clone(&corpus);
            // distinct etas per manifest so nothing dedupes within one
            // shape; across shapes eta repeats to prove the manifest
            // name keeps the addresses apart
            (0..2).map(move |i| {
                EngineJob::new(
                    Arc::clone(&man),
                    Arc::clone(&corpus),
                    cfg(&format!("{name}-{i}"), 0.5 * (i + 1) as f64, 8),
                    vec![],
                )
            })
        })
        .collect();
    let report = engine.run(jobs);
    assert_eq!(report.completed, 6);
    assert_eq!(report.failed, 0);
    // same config under different manifests must NOT collide in the
    // cache: the manifest name is part of the content address
    assert_eq!(report.executed, 6);
    assert_eq!(counter.load(Ordering::SeqCst), 6);
}
