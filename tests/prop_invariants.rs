//! Property tests (in-tree `util::prop` substrate): coordinator
//! invariants across codecs, abc rules, residual scheme, schedules, JSON
//! and the sweep machinery.

use umup::data::{Corpus, CorpusConfig};
use umup::engine::run_key;
use umup::formats::{FloatFormat, TensorStats, BF16, E4M3, E5M2, FP16};
use umup::parametrization::{
    gated_silu_scale, log_interpolate, umup_residual, Abc, EmbLrRule, HpSet, Parametrization,
    Precision, Scheme, HP_NAMES,
};
use umup::runtime::{TensorMeta, WeightKind};
use umup::train::{RunConfig, Schedule};
use umup::util::prop::{check, Config};
use umup::util::Json;

const FORMATS: [FloatFormat; 4] = [E4M3, E5M2, FP16, BF16];

#[test]
fn codec_idempotent_and_monotone() {
    check("codec idempotent", Config::default(), |g| {
        let fmt = FORMATS[g.rng.below(4)];
        let xs = g.wide_vec(64);
        for &x in &xs {
            let q = fmt.quantize(x);
            assert_eq!(q.to_bits(), fmt.quantize(q).to_bits(), "{x} {}", fmt.name);
        }
        // monotone on a sorted pair
        let (a, b) = (g.wide_f32(), g.wide_f32());
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(fmt.quantize(lo) <= fmt.quantize(hi));
    });
}

#[test]
fn codec_error_bounded_by_half_ulp() {
    check("codec error bound", Config::default(), |g| {
        let fmt = FORMATS[g.rng.below(4)];
        let x = g.wide_f32();
        if (x.abs() as f64) > fmt.max_value() {
            return; // saturation region
        }
        let q = fmt.quantize(x) as f64;
        let ulp = ((x.abs() as f64) * 2f64.powi(-(fmt.mant_bits as i32)))
            .max(fmt.min_subnormal());
        assert!((q - x as f64).abs() <= ulp / 1.99, "{x} -> {q} ({})", fmt.name);
    });
}

#[test]
fn codec_sign_symmetric() {
    check("codec sign symmetry", Config::default(), |g| {
        let fmt = FORMATS[g.rng.below(4)];
        let x = g.wide_f32();
        assert_eq!(fmt.quantize(-x).to_bits(), (-fmt.quantize(x)).to_bits());
    });
}

#[test]
fn abc_symmetry_preserves_effective_forward() {
    // Eq. 2: (A·θ, B/θ, C/θ) leaves A·B (the effective init-weight
    // contribution to activations) invariant — the forward pass at init
    // is unchanged under the shift.
    check("abc theta shift", Config::default(), |g| {
        let t = TensorMeta {
            name: "h".into(),
            shape: vec![64, 64],
            kind: WeightKind::Hidden,
            fan_in: 1 << g.usize_in(3, 10),
            fan_out: 64,
            offset: 0,
            size: 64 * 64,
        };
        let p = Parametrization::new(match g.rng.below(3) {
            0 => Scheme::Mup,
            1 => Scheme::Umup,
            _ => Scheme::Intermediate,
        });
        let hp = HpSet::with_eta(2f64.powf(g.rng.range(-10.0, 2.0)));
        let abc = Abc::of(&p, &hp, &t, 64, 4);
        let theta = 2f64.powf(g.rng.range(-6.0, 6.0));
        let shifted = abc.theta_shift(theta);
        let eff = abc.a * abc.b;
        let eff2 = shifted.a * shifted.b;
        assert!((eff - eff2).abs() <= 1e-12 * eff.abs().max(1e-30));
        // and the Adam-relative update size C/B is invariant up to θ²...
        // what IS exactly invariant is (A·C): the activation-space update
        let upd = abc.a * abc.c;
        let upd2 = shifted.a * shifted.c;
        assert!((upd - upd2).abs() <= 1e-12 * upd.abs().max(1e-30));
    });
}

#[test]
fn umup_residual_invariants() {
    check("residual tau scheme", Config::default(), |g| {
        let n_layers = g.usize_in(1, 24);
        let layer = g.rng.below(n_layers);
        let r = 2f64.powf(g.rng.range(-3.0, 3.0));
        let rho = 2f64.powf(g.rng.range(-3.0, 3.0));
        let c = umup_residual(layer, n_layers, r, rho);
        assert!(c.is_unit_preserving(1e-9));
        // coefficients positive, skip dominates late layers less than
        // early ones is NOT required; but τ must decrease with depth
        // index (later branches contribute less relative variance):
        if layer + 1 < n_layers {
            let c2 = umup_residual(layer + 1, n_layers, r, rho);
            assert!(c2.attn_a <= c.attn_a + 1e-12);
        }
        // ratio invariant: attn_τ / ffn_τ' relationship from Eqs. 30/31
        let tau_a = c.attn_a / c.attn_b;
        // reconstruct Eq. 29 numerator ratio: tau_a² · denom = aa2
        let aa2 = rho * rho * 2.0 / (rho * rho + 1.0) * r * r;
        let ell = layer as f64;
        let af2 = 2.0 / (rho * rho + 1.0) * r * r;
        let denom = n_layers as f64 + ell * aa2 + ell * af2;
        assert!((tau_a * tau_a - aa2 / denom).abs() < 1e-9);
    });
}

#[test]
fn schedule_bounded_and_warmup_monotone() {
    check("schedule bounds", Config::default(), |g| {
        let total = g.usize_in(2, 4096) as u64;
        let warmup = g.rng.below(total as usize) as u64;
        let peak = 2f64.powf(g.rng.range(-12.0, 3.0));
        let s = Schedule::standard(peak, total, warmup);
        let mut prev = 0.0;
        for t in 1..=total {
            let lr = s.lr_at(t);
            assert!(lr >= 0.0 && lr <= peak * (1.0 + 1e-12), "t={t} lr={lr}");
            if t <= warmup {
                assert!(lr >= prev - 1e-15);
            }
            prev = lr;
        }
        // cosine floor: final LR = 10% of peak
        assert!((s.lr_at(total) - 0.1 * peak).abs() < 1e-9 * peak);
    });
}

#[test]
fn json_round_trip_fuzz() {
    check("json round trip", Config { cases: 128, ..Default::default() }, |g| {
        // build a random JSON value
        fn build(g: &mut umup::util::prop::Gen, depth: usize) -> Json {
            match if depth > 3 { g.rng.below(4) } else { g.rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(g.rng.f64() < 0.5),
                2 => Json::Num((g.rng.range(-1e9, 1e9) * 1000.0).round() / 1000.0),
                3 => Json::Str(format!("s{}-\"quoted\"\n\t{}", g.case, g.rng.below(100))),
                4 => Json::Arr((0..g.rng.below(5)).map(|_| build(g, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..g.rng.below(5))
                        .map(|i| (format!("k{i}"), build(g, depth + 1)))
                        .collect(),
                ),
            }
        }
        let v = build(g, 0);
        let round = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, round);
    });
}

#[test]
fn emb_lr_rule_transfer_identity() {
    // §4.4: under the sqrt rule the *effective* emb LR at width w equals
    // the proxy LR scaled by sqrt(base/w): check the rule's defining
    // functional equation factor(w1)·sqrt(w1) == factor(w2)·sqrt(w2).
    check("emb lr rule", Config::default(), |g| {
        let w1 = 1 << g.usize_in(5, 12);
        let w2 = 1 << g.usize_in(5, 12);
        let r = EmbLrRule::InvSqrtFanOut;
        let f1 = r.factor(w1 as f64, 1.0 / w1 as f64) * (w1 as f64).sqrt();
        let f2 = r.factor(w2 as f64, 1.0 / w2 as f64) * (w2 as f64).sqrt();
        assert!((f1 - f2).abs() < 1e-9);
    });
}

#[test]
fn unit_scaling_factors_positive_and_monotone() {
    check("unit scaling factors", Config::default(), |g| {
        let a = 2f64.powf(g.rng.range(-6.0, 6.0));
        let b = 2f64.powf(g.rng.range(-6.0, 6.0));
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        // gated-silu multiplier decreases as alpha grows (σ grows)
        assert!(gated_silu_scale(lo) >= gated_silu_scale(hi) - 1e-12);
        // log_interpolate stays within [min, max] of its bounds
        let w = g.rng.f64();
        let v = log_interpolate(w, hi, lo);
        assert!(v >= lo - 1e-12 && v <= hi + 1e-12);
    });
}

#[test]
fn tensor_stats_scale_equivariant() {
    check("stats scale equivariance", Config::default(), |g| {
        let xs = g.wide_vec(256);
        // use a moderate scale factor to avoid overflow
        let k = 2f32.powi(g.usize_in(0, 8) as i32);
        let st = TensorStats::of(&xs);
        let scaled: Vec<f32> = xs.iter().map(|x| x * k).collect();
        let st2 = TensorStats::of(&scaled);
        if st.rms.is_finite() && st2.rms.is_finite() && st.rms > 0.0 && st.rms < 1e30 {
            assert!((st2.rms / st.rms / k as f64 - 1.0).abs() < 1e-4);
        }
    });
}

// ----------------------------------------------------------------------
// run_key properties (engine cache addressing): field-order
// independence, golden-key stability across default changes, and
// collision-freedom over a config/manifest/corpus grid.

fn key_corpus(vocab: usize, n_tokens: usize) -> Corpus {
    Corpus {
        config: CorpusConfig { vocab, n_tokens, ..Default::default() },
        tokens: vec![],
        n_train: 0,
    }
}

#[test]
fn run_key_is_independent_of_hp_set_order_and_label() {
    check("run_key order independence", Config { cases: 64, ..Default::default() }, |g| {
        let corpus = key_corpus(64, 1000);
        // random HP values, assigned in two g-derived orders
        let values: Vec<(usize, f64)> = (0..HP_NAMES.len())
            .map(|i| (i, 2f64.powf(g.rng.range(-3.0, 3.0))))
            .collect();
        let mut forward = RunConfig::quick(
            &format!("label-a-{}", g.case),
            Parametrization::new(Scheme::Umup),
            HpSet::default(),
            32,
        );
        let mut backward = RunConfig::quick(
            &format!("label-b-{}", g.case),
            Parametrization::new(Scheme::Umup),
            HpSet::default(),
            32,
        );
        for &(i, v) in &values {
            assert!(forward.hp.set(HP_NAMES[i], v));
        }
        for &(i, v) in values.iter().rev() {
            assert!(backward.hp.set(HP_NAMES[i], v));
        }
        // same content, different labels and set order -> same canonical
        // form, same address
        assert_eq!(forward.canonical_json().dump(), backward.canonical_json().dump());
        assert_eq!(
            run_key("w64", &corpus, &forward),
            run_key("w64", &corpus, &backward)
        );
        // ...and any single HP perturbation moves the address
        let j = g.rng.below(HP_NAMES.len());
        let old = backward.hp.get(HP_NAMES[j]).unwrap();
        backward.hp.set(HP_NAMES[j], old * 2.0);
        assert_ne!(
            run_key("w64", &corpus, &forward),
            run_key("w64", &corpus, &backward),
            "perturbing {} must change the key",
            HP_NAMES[j]
        );
    });
}

/// Golden content addresses: these keys are what on-disk caches are
/// addressed by, so they must be stable across refactors.  A failure
/// here means persisted caches stop resuming — if the change is
/// deliberate (cache format break), update tests/data/run_key_golden.json
/// with the printed key; otherwise fix the regression.
#[test]
fn run_key_matches_golden_keys() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/run_key_golden.json");
    let text = std::fs::read_to_string(path).expect("golden key file");
    let goldens = Json::parse(&text).unwrap();
    let mut checked = 0;
    for g in goldens.get("goldens").unwrap().as_arr().unwrap() {
        let name = g.get("name").unwrap().as_str().unwrap();
        let manifest = g.get("manifest").unwrap().as_str().unwrap();
        let vocab = g.get("vocab").unwrap().as_usize().unwrap();
        let n_tokens = g.get("n_tokens").unwrap().as_usize().unwrap();
        let expected = g.get("key").unwrap().as_str().unwrap();
        let cfg = match name {
            "umup-quick-defaults" => RunConfig::quick(
                "any-label",
                Parametrization::new(Scheme::Umup),
                HpSet::default(),
                64,
            ),
            "mup-fp8-tweaked" => {
                let mut c = RunConfig::quick(
                    "x",
                    Parametrization::new(Scheme::Mup),
                    HpSet::with_eta(0.25),
                    32,
                );
                c.seed = 7;
                c.precision = Precision::Fp8Paper;
                c.rms_sites = vec!["w.head".to_string()];
                c.lr_tweaks = vec![("emb".to_string(), 4.0)];
                c
            }
            "sp-quick-16" => RunConfig::quick(
                "y",
                Parametrization::new(Scheme::Sp),
                HpSet::default(),
                16,
            ),
            other => panic!("unknown golden case {other:?}"),
        };
        let corpus = key_corpus(vocab, n_tokens);
        let key = run_key(manifest, &corpus, &cfg);
        assert_eq!(
            key,
            expected,
            "golden key {name:?} drifted — persisted run caches will stop \
             resuming.  If this is a deliberate cache-format/default change, \
             update tests/data/run_key_golden.json; canonical json was:\n{}",
            cfg.canonical_json().dump()
        );
        checked += 1;
    }
    assert_eq!(checked, 3, "golden file must cover all pinned cases");
}

#[test]
fn run_key_collision_free_over_config_grid() {
    // a deterministic grid across every address dimension: any collision
    // is a real aliasing bug (two different runs sharing a cache slot)
    let mut seen = std::collections::BTreeMap::new();
    let mut n = 0usize;
    for manifest in ["w32_d2", "w64_d4", "w128_d4", "w256_d8"] {
        for (vocab, n_tokens) in [(64usize, 1000usize), (256, 200_000)] {
            let corpus = key_corpus(vocab, n_tokens);
            for scheme in [Scheme::Sp, Scheme::Mup, Scheme::Umup] {
                for eta_i in 1..=3u32 {
                    for steps in [8u64, 16] {
                        for seed in 0..2i32 {
                            let mut cfg = RunConfig::quick(
                                "grid",
                                Parametrization::new(scheme),
                                HpSet::with_eta(0.25 * eta_i as f64),
                                steps,
                            );
                            cfg.seed = seed;
                            let key = run_key(manifest, &corpus, &cfg);
                            let desc = format!(
                                "{manifest}/{vocab}/{n_tokens}/{scheme:?}/{eta_i}/{steps}/{seed}"
                            );
                            if let Some(prev) = seen.insert(key.clone(), desc.clone()) {
                                panic!("key {key} collides: {prev} vs {desc}");
                            }
                            n += 1;
                        }
                    }
                }
            }
        }
    }
    assert_eq!(seen.len(), n);
    assert_eq!(n, 4 * 2 * 3 * 3 * 2 * 2);
}
