//! Robustness suite for the distributed stack: the deterministic
//! fault-injection proxy (`repro chaos`), deadline supervision
//! (`--job-timeout`), graceful drain on SIGTERM, shared-secret token
//! auth, and the `repro ctl` client deadline.
//!
//! The load-bearing assertion is byte-identity: whatever a `FaultPlan`
//! does to the wire — garbage replies, torn frames, dropped
//! connections, injected latency, silent stalls — the drained cache
//! must equal the clean in-process run bit for bit, with the engine's
//! counter partition intact and no job recorded twice.  Faults are
//! ordinal-triggered (no clocks, no randomness), so every run of this
//! suite exercises the exact same failure schedule.

mod common;

use std::collections::BTreeSet;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::AtomicUsize;
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{det_mock_engine, key_of_line, shared_job_list, sorted_segment_lines};
use umup::engine::{
    Backend, Engine, EngineConfig, Event, EventBus, NetworkBackend, ProcessBackend,
};
use umup::util::Json;

fn repro_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_repro"))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("umup-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Pin the cache timestamp so segment lines are byte-reproducible.
/// Spawned workers inherit the variable, so their reply lines carry the
/// same pinned stamp as the in-process reference.
fn pin_cache_ts() {
    std::env::set_var("UMUP_CACHE_TS", "1700000000");
}

/// Spawn a repro subcommand that announces `listening <addr>` on stdout
/// (worker --listen and the chaos proxy share the format) and read the
/// address back.
fn spawn_announced(mut cmd: Command, what: &str) -> (Child, String) {
    cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::null());
    let mut child = cmd.spawn().unwrap_or_else(|e| panic!("spawning {what}: {e}"));
    let stdout = child.stdout.take().expect("stdout is piped");
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).expect("reading the listen announcement");
    let addr = line
        .strip_prefix("listening ")
        .unwrap_or_else(|| panic!("unexpected {what} announcement {line:?}"))
        .trim()
        .to_string();
    (child, addr)
}

fn spawn_listen_worker(envs: &[(&str, &str)]) -> (Child, String) {
    let mut cmd = Command::new(repro_exe());
    cmd.arg("worker").arg("--mock").arg("--listen").arg("127.0.0.1:0");
    for (k, v) in envs {
        cmd.env(k, v);
    }
    spawn_announced(cmd, "listen worker")
}

fn spawn_chaos_proxy(upstream: &str, faults: &str) -> (Child, String) {
    let mut cmd = Command::new(repro_exe());
    cmd.arg("chaos")
        .arg("--listen")
        .arg("127.0.0.1:0")
        .arg("--upstream")
        .arg(upstream)
        .arg("--faults")
        .arg(faults);
    spawn_announced(cmd, "chaos proxy")
}

fn kill_fleet(fleet: Vec<Child>) {
    for mut child in fleet {
        let _ = child.kill();
        let _ = child.wait();
    }
}

/// The clean in-process run every chaotic run must match byte for byte.
fn reference_lines(tag: &str) -> Vec<String> {
    pin_cache_ts();
    let dir = tmp_dir(tag);
    let jobs = shared_job_list();
    let n_jobs = jobs.len();
    let engine = det_mock_engine(
        EngineConfig {
            workers: 2,
            cache_dir: Some(dir.clone()),
            resume: true,
            ..EngineConfig::default()
        },
        Arc::new(AtomicUsize::new(0)),
    );
    let report = engine.run(jobs);
    drop(engine);
    assert_eq!(report.completed, n_jobs, "the clean reference run must complete");
    let lines = sorted_segment_lines(&dir);
    let _ = std::fs::remove_dir_all(&dir);
    lines
}

fn fresh_engine(backend: Arc<dyn Backend>, dir: &Path) -> Engine {
    Engine::with_backend(
        EngineConfig {
            workers: 4,
            cache_dir: Some(dir.to_path_buf()),
            resume: true,
            ..EngineConfig::default()
        },
        backend,
    )
    .expect("backend health probe")
}

// ------------------------------------------------------- chaos matrix

/// The acceptance test for the fault-injection layer: a 4-worker fleet
/// with one worker behind the chaos proxy survives every `FaultPlan` in
/// the matrix — the engine re-dispatches the wounded window within its
/// restart budget and the drained cache is byte-identical to the clean
/// in-process run.  Only the silent-stall plan needs `--job-timeout`
/// armed; every other fault surfaces as an I/O error on its own.
#[test]
fn chaos_matrix_is_byte_identical_to_the_clean_run() {
    pin_cache_ts();
    let reference = reference_lines("matrix-ref");
    let n_jobs = shared_job_list().len();
    let plans: &[(&str, Option<Duration>)] = &[
        ("garbage-reply:1", None),
        ("tear-frame:2", None),
        ("drop-conn:5", None),
        ("delay-ms:25", None),
        ("stall-after:3", Some(Duration::from_secs(2))),
    ];
    for (spec, job_timeout) in plans {
        let dir = tmp_dir(&format!("matrix-{}", spec.replace([':', ','], "-")));
        // one proxied worker plus three direct ones: a one-shot fault
        // costs at most one reconnect, and round-robin failover moves
        // the wounded engine slot onto a healthy direct endpoint
        let mut fleet = Vec::new();
        let (child, upstream) = spawn_listen_worker(&[]);
        fleet.push(child);
        let (proxy, proxy_addr) = spawn_chaos_proxy(&upstream, spec);
        fleet.push(proxy);
        let mut addrs = vec![proxy_addr];
        for _ in 0..3 {
            let (child, addr) = spawn_listen_worker(&[]);
            fleet.push(child);
            addrs.push(addr);
        }
        let backend = Arc::new(
            NetworkBackend::new(&addrs.join(","))
                .expect("backend construction")
                .with_max_restarts(2)
                .with_job_timeout(*job_timeout),
        );
        let engine = fresh_engine(backend, &dir);
        let report = engine.run(shared_job_list());
        drop(engine);
        assert_eq!(report.failed, 0, "plan {spec}: no job may fail");
        assert_eq!(report.completed, n_jobs, "plan {spec}: every job must complete");
        assert_eq!(
            report.executed + report.cache_hits + report.deduped + report.skipped + report.cancelled,
            n_jobs,
            "plan {spec}: counter partition broken (executed {} hits {} dups {} skips {} cancelled {})",
            report.executed,
            report.cache_hits,
            report.deduped,
            report.skipped,
            report.cancelled
        );
        let lines = sorted_segment_lines(&dir);
        assert_eq!(lines.len(), n_jobs, "plan {spec}: exactly one cache line per job");
        let keys: BTreeSet<String> = lines.iter().map(|l| key_of_line(l)).collect();
        assert_eq!(keys.len(), n_jobs, "plan {spec}: a job was recorded twice");
        assert_eq!(
            lines, reference,
            "plan {spec}: the drained cache must be byte-identical to the clean run"
        );
        kill_fleet(fleet);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// ------------------------------------------- hung-but-alive supervision

/// A worker that accepts a job and never replies — alive, so no EOF or
/// reset ever surfaces — is exactly what `--job-timeout` exists for:
/// the read deadline fires, the connection is torn down, and the unacked
/// window is re-dispatched to a healthy endpoint.
#[test]
fn hung_worker_under_a_job_deadline_recovers_on_the_network_backend() {
    pin_cache_ts();
    let reference = reference_lines("hang-net-ref");
    let n_jobs = shared_job_list().len();
    let dir = tmp_dir("hang-net");
    let marker = tmp_dir("hang-net-marker").with_extension("once");
    let _ = std::fs::remove_file(&marker);
    let marker_s = marker.to_str().unwrap().to_string();
    let mut fleet = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..4 {
        let (child, addr) = spawn_listen_worker(&[
            ("UMUP_MOCK_FAIL", "hang"),
            ("UMUP_MOCK_FAIL_ONCE", &marker_s),
        ]);
        fleet.push(child);
        addrs.push(addr);
    }
    let backend = Arc::new(
        NetworkBackend::new(&addrs.join(","))
            .expect("backend construction")
            .with_max_restarts(2)
            .with_job_timeout(Some(Duration::from_secs(1))),
    );
    let engine = fresh_engine(Arc::clone(&backend) as Arc<dyn Backend>, &dir);
    let report = engine.run(shared_job_list());
    drop(engine);
    assert!(marker.exists(), "the hang injection never fired");
    assert_eq!(report.failed, 0, "the hung window must be re-dispatched, not failed");
    assert_eq!(report.completed, n_jobs);
    assert!(backend.restarts() >= 1, "the stalled connection must be accounted as a restart");
    let lines = sorted_segment_lines(&dir);
    assert_eq!(lines, reference, "deadline recovery must not corrupt the cache");
    kill_fleet(fleet);
    let _ = std::fs::remove_file(&marker);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The same hang on the pipe backend: the watchdog SIGKILLs the wedged
/// child when the deadline expires, the slot restarts within budget,
/// and a `worker_stalled` event lands on the bus.
#[test]
fn hung_worker_under_a_job_deadline_recovers_on_the_process_backend() {
    pin_cache_ts();
    let reference = reference_lines("hang-proc-ref");
    let n_jobs = shared_job_list().len();
    let dir = tmp_dir("hang-proc");
    let marker = tmp_dir("hang-proc-marker").with_extension("once");
    let _ = std::fs::remove_file(&marker);
    let marker_s = marker.to_str().unwrap().to_string();
    let exe = repro_exe();
    let backend = Arc::new(
        ProcessBackend::new(move |_worker| {
            let mut cmd = Command::new(&exe);
            cmd.arg("worker").arg("--mock");
            cmd.env("UMUP_MOCK_FAIL", "hang");
            cmd.env("UMUP_MOCK_FAIL_ONCE", &marker_s);
            cmd
        })
        .with_max_restarts(2)
        .with_job_timeout(Some(Duration::from_secs(1))),
    );
    let bus = EventBus::new();
    let stream = bus.subscribe(4096);
    let engine = Engine::with_backend(
        EngineConfig {
            workers: 4,
            cache_dir: Some(dir.clone()),
            resume: true,
            events: Some(bus.clone()),
            ..EngineConfig::default()
        },
        Arc::clone(&backend) as Arc<dyn Backend>,
    )
    .expect("backend health probe");
    let report = engine.run(shared_job_list());
    drop(engine);
    let restarts = backend.restarts();
    drop(backend);
    drop(bus);
    assert!(marker.exists(), "the hang injection never fired");
    assert_eq!(report.failed, 0, "the hung window must be re-dispatched, not failed");
    assert_eq!(report.completed, n_jobs);
    assert!(restarts >= 1, "the watchdog kill must be accounted as a restart");
    let saw_stall = stream.into_iter().any(|env| matches!(env.event, Event::WorkerStalled { .. }));
    assert!(saw_stall, "an expired deadline must publish a worker_stalled event");
    let lines = sorted_segment_lines(&dir);
    assert_eq!(lines, reference, "watchdog recovery must not corrupt the cache");
    let _ = std::fs::remove_file(&marker);
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------ graceful drain

/// SIGTERM to a unix-socket listen worker: the accept loop stops, the
/// socket file is unlinked, and the process exits with the distinct
/// drained code so supervisors can tell a drain from a crash.
#[cfg(unix)]
#[test]
fn sigterm_drains_a_listen_worker_and_unlinks_its_socket() {
    use umup::util::signal;
    let dir = tmp_dir("drain-sock");
    std::fs::create_dir_all(&dir).unwrap();
    let sock = dir.join("worker.sock");
    let mut cmd = Command::new(repro_exe());
    cmd.arg("worker").arg("--mock").arg("--listen").arg(format!("unix:{}", sock.display()));
    let (mut child, _addr) = spawn_announced(cmd, "unix listen worker");
    assert!(sock.exists(), "the unix socket must exist while serving");
    assert!(signal::send(child.id(), signal::SIGTERM), "sending SIGTERM");
    let status = child.wait().expect("waiting for the drained worker");
    assert_eq!(
        status.code(),
        Some(signal::EXIT_DRAINED),
        "a drain must exit with the drained code, not die on the signal"
    );
    assert!(!sock.exists(), "the drained worker must unlink its unix socket");
    let _ = std::fs::remove_dir_all(&dir);
}

/// SIGTERM to a `repro serve` daemon: in-flight sweeps are cancelled,
/// the owner loop drains, and the process exits with the drained code.
#[cfg(unix)]
#[test]
fn sigterm_drains_a_serve_daemon() {
    use umup::util::signal;
    let mut cmd = Command::new(repro_exe());
    cmd.arg("serve").arg("--addr").arg("127.0.0.1:0").arg("--workers").arg("2");
    cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::null());
    let mut daemon = cmd.spawn().expect("spawning repro serve");
    let stdout = daemon.stdout.take().expect("serve stdout is piped");
    let mut reader = BufReader::new(stdout);
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("reading serve stdout");
        assert_ne!(n, 0, "serve exited before announcing its endpoint");
        if line.starts_with("serving ") {
            break;
        }
    }
    assert!(signal::send(daemon.id(), signal::SIGTERM), "sending SIGTERM");
    let status = daemon.wait().expect("waiting for the drained daemon");
    assert_eq!(
        status.code(),
        Some(signal::EXIT_DRAINED),
        "a drain must exit with the drained code, not die on the signal"
    );
}

// -------------------------------------------------------- token auth

/// A token-armed worker rejects token-less dials at the health probe
/// (fast, with the env-var hint) and serves a matching dial normally.
#[test]
fn token_auth_gates_the_worker_wire_handshake() {
    pin_cache_ts();
    let (child, addr) = spawn_listen_worker(&[("UMUP_TOKEN", "sesame")]);
    let backend = NetworkBackend::new(&addr).expect("backend construction");
    let err = Engine::with_backend(
        EngineConfig { workers: 1, ..EngineConfig::default() },
        Arc::new(backend) as Arc<dyn Backend>,
    )
    .err()
    .expect("a token-less dial of a token-armed worker must fail its health probe");
    let msg = format!("{err:#}");
    assert!(msg.contains("UMUP_TOKEN"), "the auth error must name the fix: {msg}");

    let backend = NetworkBackend::new(&addr)
        .expect("backend construction")
        .with_token(Some("sesame".to_string()));
    let engine = Engine::with_backend(
        EngineConfig { workers: 1, ..EngineConfig::default() },
        Arc::new(backend) as Arc<dyn Backend>,
    )
    .expect("a matching token must pass the handshake");
    let jobs: Vec<_> = shared_job_list().into_iter().take(4).collect();
    let report = engine.run(jobs);
    assert_eq!(report.completed, 4);
    assert_eq!(report.failed, 0);
    drop(engine);
    kill_fleet(vec![child]);
}

/// The same gate on the control plane: a token-armed daemon turns away
/// token-less `ctl` dials before any RPC is sent, answers matching ones,
/// and shuts down cleanly on a tokened `ctl shutdown`.
#[test]
fn ctl_token_round_trip_against_a_token_armed_daemon() {
    let mut cmd = Command::new(repro_exe());
    cmd.arg("serve")
        .arg("--addr")
        .arg("127.0.0.1:0")
        .arg("--workers")
        .arg("2")
        .arg("--token")
        .arg("sesame");
    cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::null());
    let mut daemon = cmd.spawn().expect("spawning repro serve");
    let stdout = daemon.stdout.take().expect("serve stdout is piped");
    let mut reader = BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("reading serve stdout");
        assert_ne!(n, 0, "serve exited before announcing its endpoint");
        if let Some(a) = line.strip_prefix("serving ") {
            break a.trim().to_string();
        }
    };

    let out = Command::new(repro_exe())
        .arg("ctl")
        .arg("status")
        .arg("--addr")
        .arg(&addr)
        .output()
        .expect("running repro ctl");
    assert!(!out.status.success(), "a token-less ctl dial must fail against an armed daemon");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("UMUP_TOKEN"), "the auth error must name the fix: {stderr}");

    let status = ctl_json(&addr, "status", &["--token", "sesame"]);
    assert!(status.get("sweeps").is_ok(), "a tokened status must answer: {status:?}");
    let reply = ctl_json(&addr, "shutdown", &["--token", "sesame"]);
    assert!(reply.get("shutdown").unwrap().as_bool().unwrap());
    let exit = daemon.wait().expect("waiting for the daemon");
    assert!(exit.success(), "ctl shutdown must exit the daemon cleanly");
}

fn ctl_json(addr: &str, verb: &str, extra: &[&str]) -> Json {
    let out = Command::new(repro_exe())
        .arg("ctl")
        .arg(verb)
        .args(extra)
        .arg("--addr")
        .arg(addr)
        .output()
        .expect("running repro ctl");
    assert!(
        out.status.success(),
        "ctl {verb} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    Json::parse(String::from_utf8_lossy(&out.stdout).trim()).expect("ctl output is JSON")
}

// ------------------------------------------------------- ctl deadline

/// A daemon that accepts the dial and then never speaks: the ctl client
/// deadline must expire with a nonzero exit and an error that names the
/// address, the elapsed budget, and the `--timeout` knob — not hang.
#[test]
fn ctl_timeout_expiry_is_a_pointed_error() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("binding the mute daemon");
    let addr = listener.local_addr().unwrap().to_string();
    // keep accepted sockets open so ctl sees a live, silent peer
    let _hold = std::thread::spawn(move || {
        let mut held = Vec::new();
        while let Ok((sock, _)) = listener.accept() {
            held.push(sock);
        }
    });
    let started = Instant::now();
    let out = Command::new(repro_exe())
        .arg("ctl")
        .arg("status")
        .arg("--addr")
        .arg(&addr)
        .arg("--timeout")
        .arg("1")
        .output()
        .expect("running repro ctl");
    assert!(!out.status.success(), "a silent daemon must fail ctl, not hang it");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("within 1s") && stderr.contains("--timeout"),
        "the deadline error must point at the knob: {stderr}"
    );
    assert!(started.elapsed() < Duration::from_secs(20), "ctl overshot its deadline");
}
