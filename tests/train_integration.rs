//! Integration: the training loop, parametrization vectors and the run
//! engine against real compiled artifacts.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use umup::data::{Corpus, CorpusConfig};
use umup::engine::{Engine, EngineConfig};
use umup::parametrization::{
    attention_out_scale, HpSet, Parametrization, Precision, RuntimeVectors, Scheme,
};
use umup::runtime::Manifest;
use umup::sweep::SweepJob;
use umup::train::{RunConfig, Schedule};

fn artifact(name: &str) -> Arc<Manifest> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name);
    Arc::new(Manifest::load(&dir).unwrap())
}

/// Compiled artifacts come from the Python AOT pipeline (`make
/// artifacts`) and are not checked in; on runners without them these
/// tests skip rather than fail (the engine tests in `tests/engine.rs`
/// cover the artifact-free machinery).
macro_rules! require_artifacts {
    () => {
        if !PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").is_dir() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
    };
}

fn tiny_corpus(vocab: usize) -> Corpus {
    Corpus::generate(CorpusConfig { vocab, n_tokens: 120_000, ..Default::default() })
}

fn quick_cfg(scheme: Scheme, eta: f64, steps: u64) -> RunConfig {
    let mut cfg =
        RunConfig::quick(scheme.name(), Parametrization::new(scheme), HpSet::with_eta(eta), steps);
    cfg.schedule = Schedule::standard(eta, steps, (steps / 4).max(1));
    cfg
}

/// A single-worker engine for runner-level tests.
fn solo_engine() -> Engine {
    Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() }).unwrap()
}

#[test]
fn schemes_produce_distinct_trajectories() {
    require_artifacts!();
    let man = artifact("w32_d2_b4_t16_v64");
    let corpus = tiny_corpus(man.spec.vocab);
    let engine = solo_engine();
    let runner = engine.runner(&man).unwrap();
    let mut finals = Vec::new();
    for (scheme, eta) in [(Scheme::Sp, 0.01), (Scheme::Mup, 0.01), (Scheme::Umup, 0.5)] {
        let rec = runner.run(&quick_cfg(scheme, eta, 40), &corpus).unwrap();
        assert!(!rec.diverged, "{scheme:?}");
        assert!(rec.final_valid_loss < 4.2, "{scheme:?} {}", rec.final_valid_loss);
        finals.push(rec.final_valid_loss);
    }
    assert!(finals.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-6));
}

#[test]
fn umup_fp8_close_to_fp32() {
    require_artifacts!();
    let man = artifact("w32_d2_b4_t16_v64");
    let corpus = tiny_corpus(man.spec.vocab);
    let engine = solo_engine();
    let runner = engine.runner(&man).unwrap();
    let mut losses = Vec::new();
    for precision in [Precision::Fp32, Precision::Fp8Naive, Precision::Fp8Paper] {
        let mut cfg = quick_cfg(Scheme::Umup, 0.5, 50);
        cfg.precision = precision;
        let rec = runner.run(&cfg, &corpus).unwrap();
        assert!(!rec.diverged, "{precision:?}");
        losses.push(rec.final_valid_loss);
    }
    // unit scale ⇒ naive fp8 training must stay near the fp32 curve
    assert!((losses[1] - losses[0]).abs() < 0.25, "naive fp8 {losses:?}");
    assert!((losses[2] - losses[0]).abs() < 0.25, "paper fp8 {losses:?}");
}

#[test]
fn parallel_engine_matches_sequential() {
    require_artifacts!();
    let man = artifact("w32_d2_b4_t16_v64");
    let corpus = Arc::new(tiny_corpus(man.spec.vocab));
    let jobs: Vec<SweepJob> = [0.25, 0.5, 1.0]
        .iter()
        .map(|&eta| SweepJob {
            config: quick_cfg(Scheme::Umup, eta, 24),
            tag: vec![("eta".into(), eta)],
        })
        .collect();
    let eng1 = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() }).unwrap();
    let eng3 = Engine::new(EngineConfig { workers: 3, ..EngineConfig::default() }).unwrap();
    let seq = eng1.run_sweep(&man, &corpus, &jobs).unwrap();
    let par = eng3.run_sweep(&man, &corpus, &jobs).unwrap();
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        // identical jobs on identical data: bitwise-deterministic XLA CPU
        assert_eq!(a.record.final_valid_loss, b.record.final_valid_loss, "{}", a.job.config.label);
    }
}

#[test]
fn engine_cache_and_resume_skip_completed_jobs() {
    require_artifacts!();
    let man = artifact("w32_d2_b4_t16_v64");
    let corpus = Arc::new(tiny_corpus(man.spec.vocab));
    let dir = std::env::temp_dir().join(format!("umup-engine-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let jobs: Vec<SweepJob> = [0.25, 1.0]
        .iter()
        .map(|&eta| SweepJob { config: quick_cfg(Scheme::Umup, eta, 16), tag: vec![] })
        .collect();
    let eng = Engine::new(EngineConfig {
        workers: 2,
        cache_dir: Some(dir.clone()),
        ..EngineConfig::default()
    })
    .unwrap();
    let a = eng.run_sweep(&man, &corpus, &jobs).unwrap();
    assert_eq!(eng.stats().executed, jobs.len());
    // warm re-run on the same engine: pure cache hits, nothing executes
    let b = eng.run_sweep(&man, &corpus, &jobs).unwrap();
    assert_eq!(eng.stats().executed, jobs.len());
    assert_eq!(eng.stats().cache_hits, jobs.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.record.final_valid_loss, y.record.final_valid_loss);
    }
    drop(eng);
    // simulated restart: a resuming engine replays the sweep from disk
    let eng2 = Engine::new(EngineConfig {
        workers: 2,
        cache_dir: Some(dir.clone()),
        resume: true,
        ..EngineConfig::default()
    })
    .unwrap();
    let c = eng2.run_sweep(&man, &corpus, &jobs).unwrap();
    assert_eq!(eng2.stats().executed, 0, "resumed sweep must skip completed jobs");
    assert_eq!(eng2.stats().cache_hits, jobs.len());
    for (x, y) in a.iter().zip(&c) {
        assert_eq!(x.record.final_valid_loss, y.record.final_valid_loss);
        assert_eq!(x.record.diverged, y.record.diverged);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn runtime_vectors_match_paper_rules() {
    require_artifacts!();
    let man = artifact("w64_d4_b16_t64_v256");
    let p = Parametrization::new(Scheme::Umup);
    let hp = HpSet::with_eta(1.0);
    let v = RuntimeVectors::build(&man, &p, &hp, Precision::Fp8Paper).unwrap();
    let site = |n: &str| v.scales[*man.scale_sites.get(n).unwrap()] as f64;
    // hidden matmul: A = 1/sqrt(64) fwd and gx; gw = 1/sqrt(batch·seq)
    assert!((site("l0.attn.q.out") - 0.125).abs() < 1e-6);
    assert!((site("l0.attn.q.gx") - 0.125).abs() < 1e-6);
    assert!((site("l0.attn.q.gw") - 1.0 / (16f64 * 64.0).sqrt()).abs() < 1e-6);
    // head: fwd 1/fan-in, bwd 1/sqrt(fan-in) (cut edge)
    assert!((site("head.out") - 1.0 / 64.0).abs() < 1e-9);
    assert!((site("head.gx") - 0.125).abs() < 1e-6);
    // attention logit mult: 1/d_head
    assert!((site("l0.attn.logit_mult") - 1.0 / 16.0).abs() < 1e-9);
    // attention out scale matches the Table 8 empirical model
    let expect = attention_out_scale(1.0, 16, 64);
    assert!((site("l0.attn.out_scale") - expect).abs() < 1e-5);
    // residual coefficients: a²+b² = 1 per branch
    for l in 0..4 {
        for b in ["attn", "ffn"] {
            let a = site(&format!("l{l}.res.{b}.a"));
            let bb = site(&format!("l{l}.res.{b}.b"));
            assert!((a * a + bb * bb - 1.0).abs() < 1e-5);
        }
    }
    // unit init everywhere, per-tensor LR rule on hidden = 1/sqrt(64·4)
    assert!(v.init_std.iter().all(|&s| (s - 1.0).abs() < 1e-6));
    let qi = man.tensors.iter().position(|t| t.name == "l0.attn.q").unwrap();
    assert!((v.lr_scale[qi] as f64 - 1.0 / 8.0 / 2.0).abs() < 1e-6);
    // fp8-paper mask: non-critical on, critical off
    let q = |n: &str| v.qmask[*man.quant_sites.get(n).unwrap()];
    assert_eq!(q("l0.attn.q.qx"), 1.0);
    assert_eq!(q("l0.attn.o.qx"), 0.0);
    assert_eq!(q("l1.ffn.down.qw"), 0.0);
    assert_eq!(q("head.qg"), 0.0);
    assert_eq!(q("l2.ffn.up.qg"), 1.0);
}

#[test]
fn mup_lr_rule_scales_with_width() {
    require_artifacts!();
    for (name, width) in [("w32_d4_b16_t64_v256", 32usize), ("w64_d4_b16_t64_v256", 64)] {
        let man = artifact(name);
        let mut p = Parametrization::new(Scheme::Mup);
        p.base_width = 32;
        let v = RuntimeVectors::build(&man, &p, &HpSet::with_eta(1.0), Precision::Fp32).unwrap();
        let qi = man.tensors.iter().position(|t| t.name == "l0.attn.q").unwrap();
        let expect = 32.0 / width as f32; // base-fan-in/fan-in
        assert!((v.lr_scale[qi] - expect).abs() < 1e-6, "{name}");
        let hi = man.tensors.iter().position(|t| t.name == "head").unwrap();
        assert!((v.lr_scale[hi] - 1.0).abs() < 1e-6);
    }
}

#[test]
fn lr_tweaks_change_training() {
    require_artifacts!();
    let man = artifact("w32_d2_b4_t16_v64");
    let corpus = tiny_corpus(man.spec.vocab);
    let engine = solo_engine();
    let runner = engine.runner(&man).unwrap();
    let base = quick_cfg(Scheme::Umup, 0.5, 20);
    let mut tweaked = base.clone();
    tweaked.lr_tweaks = vec![("emb".into(), 4.0)];
    let a = runner.run(&base, &corpus).unwrap();
    let b = runner.run(&tweaked, &corpus).unwrap();
    assert_ne!(a.final_valid_loss, b.final_valid_loss);
}

#[test]
fn divergence_detection() {
    require_artifacts!();
    let man = artifact("w32_d2_b4_t16_v64");
    let corpus = tiny_corpus(man.spec.vocab);
    let engine = solo_engine();
    let runner = engine.runner(&man).unwrap();
    // ludicrous LR under SP must trip the divergence guard
    let rec = runner.run(&quick_cfg(Scheme::Sp, 300.0, 40), &corpus).unwrap();
    assert!(rec.diverged || rec.final_valid_loss > 4.0);
    if rec.diverged {
        assert_eq!(rec.objective(), f64::INFINITY);
    }
}

#[test]
fn registry_find_variants() {
    require_artifacts!();
    let reg =
        umup::runtime::Registry::open(&Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
            .unwrap();
    assert!(reg.find(64, 4, 16).is_ok());
    assert!(reg.find_opt(64, 4, 16, true).is_ok()); // trainable-norms variant
    assert!(reg.find(999, 4, 16).is_err());
}
