//! Shared mock fixtures for the engine test suites.
//!
//! Everything here runs without XLA artifacts: a manifest is just its
//! parsed metadata and a corpus is its generator config, which is all
//! the engine's addressing/queueing layers touch.

#![allow(dead_code)] // each test target uses its own subset

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use umup::data::{Corpus, CorpusConfig};
use umup::engine::{det_record, Engine, EngineConfig, EngineJob, MockBackend};
use umup::parametrization::{HpSet, Parametrization, Scheme};
use umup::runtime::{Manifest, Spec};
use umup::train::{RunConfig, RunRecord};

pub fn dummy_manifest(name: &str) -> Arc<Manifest> {
    Arc::new(Manifest {
        name: name.to_string(),
        dir: PathBuf::from("."),
        spec: Spec {
            width: 32,
            depth: 2,
            batch: 4,
            seq: 16,
            vocab: 64,
            head_dim: 16,
            trainable_norms: false,
        },
        tensors: vec![],
        n_params: 0,
        state_ext_len: 1,
        loss_offset: 0,
        rms_offset: 1,
        scale_sites: BTreeMap::new(),
        n_scale_sites: 0,
        quant_sites: BTreeMap::new(),
        n_quant_sites: 0,
        rms_sites: vec![],
    })
}

pub fn dummy_corpus() -> Arc<Corpus> {
    Arc::new(Corpus {
        config: CorpusConfig { vocab: 64, n_tokens: 0, ..Default::default() },
        tokens: vec![],
        n_train: 0,
    })
}

pub fn cfg(label: &str, eta: f64, steps: u64) -> RunConfig {
    RunConfig::quick(label, Parametrization::new(Scheme::Umup), HpSet::with_eta(eta), steps)
}

// ------------------------------------------------ deterministic fixtures
//
// Shared by the concurrency and driver harnesses: the same sweep and the
// same mock executor, so every process (thread, shard child, reference
// run) that executes a given key writes the byte-identical cache line
// (with `UMUP_CACHE_TS` pinned).

/// The shared sweep every writer drains: 24 distinct jobs across 3
/// manifests.  Purely deterministic — both the job set and each job's
/// mock record.
pub fn shared_job_list() -> Vec<EngineJob> {
    let corpus = dummy_corpus();
    ["w32", "w64", "w128"]
        .iter()
        .flat_map(|name| {
            let man = dummy_manifest(name);
            let corpus = Arc::clone(&corpus);
            (0..8).map(move |i| {
                EngineJob::new(
                    Arc::clone(&man),
                    Arc::clone(&corpus),
                    cfg(&format!("{name}-lr{i}"), 0.125 * (i + 1) as f64, 8),
                    vec![],
                )
            })
        })
        .collect()
}

/// Deterministic mock engine: each "run" sleeps briefly and returns the
/// canonical [`det_record`] (shared with `repro worker --mock`, so the
/// process-backend suites can demand byte-identical caches); `counter`
/// counts actual executions (not cache/dedup resolutions).
pub fn det_mock_engine(engine_cfg: EngineConfig, counter: Arc<AtomicUsize>) -> Engine {
    let backend = MockBackend::new(move |_worker| {
        let counter = Arc::clone(&counter);
        Box::new(move |job: &EngineJob| -> anyhow::Result<RunRecord> {
            std::thread::sleep(Duration::from_millis(2));
            counter.fetch_add(1, Ordering::SeqCst);
            Ok(det_record(&job.config))
        })
    });
    Engine::with_backend(engine_cfg, Arc::new(backend)).unwrap()
}

/// All non-empty lines of every `runs*.jsonl` segment in `dir`, sorted
/// (the comparison is byte-exact per line; only ordering is forgiven).
pub fn sorted_segment_lines(dir: &Path) -> Vec<String> {
    let mut lines = Vec::new();
    for seg in umup::engine::list_segments(dir).unwrap() {
        let text = std::fs::read_to_string(&seg).unwrap();
        lines.extend(text.lines().filter(|l| !l.trim().is_empty()).map(str::to_string));
    }
    lines.sort();
    lines
}

pub fn key_of_line(line: &str) -> String {
    umup::util::Json::parse(line).unwrap().get("key").unwrap().as_str().unwrap().to_string()
}
