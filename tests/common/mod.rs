//! Shared mock fixtures for the engine test suites.
//!
//! Everything here runs without XLA artifacts: a manifest is just its
//! parsed metadata and a corpus is its generator config, which is all
//! the engine's addressing/queueing layers touch.

#![allow(dead_code)] // each test target uses its own subset

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use umup::data::{Corpus, CorpusConfig};
use umup::parametrization::{HpSet, Parametrization, Scheme};
use umup::runtime::{Manifest, Spec};
use umup::train::RunConfig;

pub fn dummy_manifest(name: &str) -> Arc<Manifest> {
    Arc::new(Manifest {
        name: name.to_string(),
        dir: PathBuf::from("."),
        spec: Spec {
            width: 32,
            depth: 2,
            batch: 4,
            seq: 16,
            vocab: 64,
            head_dim: 16,
            trainable_norms: false,
        },
        tensors: vec![],
        n_params: 0,
        state_ext_len: 1,
        loss_offset: 0,
        rms_offset: 1,
        scale_sites: BTreeMap::new(),
        n_scale_sites: 0,
        quant_sites: BTreeMap::new(),
        n_quant_sites: 0,
        rms_sites: vec![],
    })
}

pub fn dummy_corpus() -> Arc<Corpus> {
    Arc::new(Corpus {
        config: CorpusConfig { vocab: 64, n_tokens: 0, ..Default::default() },
        tokens: vec![],
        n_train: 0,
    })
}

pub fn cfg(label: &str, eta: f64, steps: u64) -> RunConfig {
    RunConfig::quick(label, Parametrization::new(Scheme::Umup), HpSet::with_eta(eta), steps)
}
