"""L2 model/step/manifest contract tests."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import specs
from compile.model import make_eval, make_init, make_step
from compile.optim import hyp_vector
from compile.specs import Spec, layout, quant_sites, rms_sites, scale_sites, tensor_table

SPEC = Spec(width=32, depth=2, batch=4, seq=16, vocab=64)
MAN = layout(SPEC)


def unit_scales(man):
    """A hand-built u-μP-flavoured scales vector (mirrors the Rust engine
    approximately; exact values are tested on the Rust side)."""
    s = np.ones(man["n_scale_sites"], np.float32)
    for name, i in man["scale_sites"].items():
        if name.endswith((".out", ".gx", ".gw")) and not name.startswith("head"):
            s[i] = 1 / math.sqrt(32)
        if "logit_mult" in name:
            s[i] = 1 / 16
        if name.startswith("head."):
            s[i] = 1 / 32
        if name.endswith("res.attn.a") or name.endswith("res.ffn.a"):
            s[i] = 1 / math.sqrt(3)
        if name.endswith("res.attn.b") or name.endswith("res.ffn.b"):
            s[i] = math.sqrt(2 / 3)
    return s


def make_all():
    init = jax.jit(make_init(SPEC))
    step = jax.jit(make_step(SPEC))
    ev = jax.jit(make_eval(SPEC))
    return init, step, ev


def test_manifest_consistency():
    tensors = tensor_table(SPEC)
    off = 0
    for t in tensors:
        assert t.offset == off
        off += t.size
    assert MAN["n_params"] == off
    assert MAN["state_ext_len"] == 3 * off + 1 + len(rms_sites(SPEC))
    assert len(scale_sites(SPEC)) == MAN["n_scale_sites"]
    assert len(quant_sites(SPEC)) == MAN["n_quant_sites"]
    # sites are a permutation of 0..n
    assert sorted(scale_sites(SPEC).values()) == list(range(MAN["n_scale_sites"]))
    assert sorted(quant_sites(SPEC).values()) == list(range(MAN["n_quant_sites"]))


def test_trainable_norms_adds_tensors():
    tn = Spec(width=32, depth=2, batch=4, seq=16, vocab=64, trainable_norms=True)
    base_names = {t.name for t in tensor_table(SPEC)}
    tn_names = {t.name for t in tensor_table(tn)}
    extra = tn_names - base_names
    assert extra == {"l0.attn_norm.g", "l0.ffn_norm.g", "l1.attn_norm.g",
                     "l1.ffn_norm.g", "final_norm.g"}


def test_init_statistics():
    init, _, _ = make_all()
    n_t = len(MAN["tensors"])
    std = np.full(n_t, 0.5, np.float32)
    st = np.asarray(init(jnp.int32(7), jnp.asarray(std)))
    emb = st[: 64 * 32]
    assert abs(emb.std() - 0.5) < 0.02
    # moments and tail start at zero
    assert np.all(st[MAN["n_params"] : 3 * MAN["n_params"]] == 0)
    assert np.all(st[MAN["loss_offset"] :] == 0)
    # different seeds give different params
    st2 = np.asarray(init(jnp.int32(8), jnp.asarray(std)))
    assert not np.allclose(st[:100], st2[:100])


def test_step_trains_and_tail_is_populated():
    init, step, ev = make_all()
    n_t = len(MAN["tensors"])
    st = init(jnp.int32(0), jnp.asarray(np.ones(n_t, np.float32)))
    scales = jnp.asarray(unit_scales(MAN))
    lr_scale = jnp.asarray(np.full(n_t, 1.0, np.float32))
    qm = jnp.asarray(np.zeros(MAN["n_quant_sites"], np.float32))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 64, (4, 17)).astype(np.int32))
    losses = []
    for t in range(1, 40):
        st = step(st, toks, scales, lr_scale, hyp_vector(0.05, 0, 2**-13, 0.9, 0.999, 1e-8, t), qm)
        losses.append(float(st[MAN["loss_offset"]]))
    assert losses[0] > 3.5  # ~ln(64) at init
    assert losses[-1] < losses[0] - 1.0  # memorizes the fixed batch
    # rms tail populated (weights ~1 under unit init)
    rms = np.asarray(st[MAN["rms_offset"]:])
    names = MAN["rms_sites"]
    w_emb = rms[names.index("w.emb")]
    assert 0.9 < w_emb < 1.2
    g_rms = rms[names.index("g.l0.attn.q")]
    assert g_rms > 0


def test_lr_zero_freezes_params():
    init, step, _ = make_all()
    n_t = len(MAN["tensors"])
    st = init(jnp.int32(0), jnp.asarray(np.ones(n_t, np.float32)))
    scales = jnp.asarray(unit_scales(MAN))
    lr_scale = jnp.asarray(np.ones(n_t, np.float32))
    qm = jnp.asarray(np.zeros(MAN["n_quant_sites"], np.float32))
    toks = jnp.asarray(np.zeros((4, 17), np.int32))
    before = np.asarray(st[: MAN["n_params"]])
    st2 = step(st, toks, scales, lr_scale, hyp_vector(0.0, 0, 0, 0.9, 0.999, 1e-8, 1), qm)
    after = np.asarray(st2[: MAN["n_params"]])
    assert np.array_equal(before, after)


def test_independent_vs_coupled_wd_differ():
    init, step, _ = make_all()
    n_t = len(MAN["tensors"])
    scales = jnp.asarray(unit_scales(MAN))
    lr_scale = jnp.asarray(np.ones(n_t, np.float32))
    qm = jnp.asarray(np.zeros(MAN["n_quant_sites"], np.float32))
    toks = jnp.asarray(np.random.default_rng(1).integers(0, 64, (4, 17)).astype(np.int32))
    st0 = init(jnp.int32(0), jnp.asarray(np.ones(n_t, np.float32)))
    # same nominal decay coefficient 0.1: coupled is modulated by lr
    # (effective 0.01·0.1 = 1e-3/step) whereas independent applies 0.1
    # directly — a 100x difference in decay strength.
    none = step(st0, toks, scales, lr_scale, hyp_vector(0.01, 0.0, 0.0, 0.9, 0.999, 1e-8, 1), qm)
    coup = step(st0, toks, scales, lr_scale, hyp_vector(0.01, 0.1, 0.0, 0.9, 0.999, 1e-8, 1), qm)
    indep = step(st0, toks, scales, lr_scale, hyp_vector(0.01, 0.0, 0.1, 0.9, 0.999, 1e-8, 1), qm)
    p = MAN["n_params"]
    p_none, p_coup, p_ind = (np.asarray(v[:p]) for v in (none, coup, indep))
    # independent decay shrinks params ~10% in one step; coupled ~0.1%
    r_ind = np.linalg.norm(p_ind) / np.linalg.norm(p_none)
    r_coup = np.linalg.norm(p_coup) / np.linalg.norm(p_none)
    assert r_ind < 0.92
    assert 0.992 < r_coup < 1.0


def test_fp8_qmask_changes_compute():
    init, step, _ = make_all()
    n_t = len(MAN["tensors"])
    scales = jnp.asarray(unit_scales(MAN))
    lr_scale = jnp.asarray(np.ones(n_t, np.float32))
    toks = jnp.asarray(np.random.default_rng(2).integers(0, 64, (4, 17)).astype(np.int32))
    st0 = init(jnp.int32(0), jnp.asarray(np.ones(n_t, np.float32)))
    hyp = hyp_vector(0.05, 0, 0, 0.9, 0.999, 1e-8, 1)
    off = step(st0, toks, scales, lr_scale, hyp, jnp.asarray(np.zeros(MAN["n_quant_sites"], np.float32)))
    on = step(st0, toks, scales, lr_scale, hyp, jnp.asarray(np.ones(MAN["n_quant_sites"], np.float32)))
    l_off, l_on = float(off[MAN["loss_offset"]]), float(on[MAN["loss_offset"]])
    assert l_off != l_on  # quantization perturbs
    assert abs(l_off - l_on) < 0.1  # ...but only slightly at unit scale


def test_eval_matches_step_loss_at_lr0():
    init, step, ev = make_all()
    n_t = len(MAN["tensors"])
    scales = jnp.asarray(unit_scales(MAN))
    lr_scale = jnp.asarray(np.ones(n_t, np.float32))
    qm = jnp.asarray(np.zeros(MAN["n_quant_sites"], np.float32))
    toks = jnp.asarray(np.random.default_rng(3).integers(0, 64, (4, 17)).astype(np.int32))
    st = init(jnp.int32(0), jnp.asarray(np.ones(n_t, np.float32)))
    st2 = step(st, toks, scales, lr_scale, hyp_vector(0.0, 0, 0, 0.9, 0.999, 1e-8, 1), qm)
    loss_step = float(st2[MAN["loss_offset"]])
    e = ev(st, toks, scales, qm)
    assert np.allclose(loss_step, float(e[0]), rtol=1e-5)


def test_default_specs_cover_required_shapes():
    from compile.aot import DEFAULT_SPECS

    names = {s.name for s in DEFAULT_SPECS}
    assert "w256_d4_b16_t64_v256" in names
    assert "w64_d8_b16_t64_v256" in names
    assert "w64_d4_b8_t64_v256" in names
    assert any(s.trainable_norms for s in DEFAULT_SPECS)
    # head_dim divides every width
    for s in DEFAULT_SPECS:
        assert s.width % s.head_dim == 0
