"""L2 scaled-op semantics: the scale hooks must put exactly the right
factor on exactly the right pass (the whole parametrization engine rests
on this contract)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import ops


def test_scale_fb_forward_and_backward():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(32), jnp.float32)

    def f(x):
        return jnp.sum(ops.scale_fb(x, jnp.float32(3.0), jnp.float32(7.0)))

    y, g = jax.value_and_grad(f)(x)
    assert np.allclose(y, 3.0 * float(jnp.sum(x)), rtol=1e-6)
    assert np.allclose(np.asarray(g), 7.0, rtol=1e-6)  # grad of sum is 1 * bwd


def test_scaled_matmul_three_scales():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
    zero = jnp.float32(0.0)

    def f(x, w):
        y = ops.scaled_matmul(x, w, jnp.float32(2.0), jnp.float32(5.0),
                              jnp.float32(11.0), zero, zero, zero)
        return jnp.sum(y)

    y = f(x, w)
    assert np.allclose(float(y), 2.0 * float(jnp.sum(x @ w)), rtol=1e-5)
    gx = jax.grad(f, argnums=0)(x, w)
    gw = jax.grad(f, argnums=1)(x, w)
    ones = jnp.ones((8, 4), jnp.float32)
    assert np.allclose(np.asarray(gx), np.asarray(ones @ w.T) * 5.0, rtol=1e-5)
    assert np.allclose(np.asarray(gw), np.asarray(x.T @ ones) * 11.0, rtol=1e-5)


def test_scaled_matmul_batched_x():
    """3-D activations contract all leading axes in the weight grad."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 5, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 3)), jnp.float32)
    one = jnp.float32(1.0)
    zero = jnp.float32(0.0)

    def f(w):
        return jnp.sum(ops.scaled_matmul(x, w, one, one, one, zero, zero, zero))

    gw = jax.grad(f)(w)
    expect = np.tensordot(np.asarray(x), np.ones((2, 5, 3), np.float32),
                          axes=((0, 1), (0, 1)))
    assert np.allclose(np.asarray(gw), expect, rtol=1e-5)


def test_quantized_matmul_uses_quantized_operands():
    from compile.kernels import ref

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, 8)) * 3, jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 4)) * 3, jnp.float32)
    one = jnp.float32(1.0)
    y = ops.scaled_matmul(x, w, one, one, one, one, one, one)
    xq = ref.quantize_ref(x, ref.E4M3)
    wq = ref.quantize_ref(w, ref.E4M3)
    assert np.allclose(np.asarray(y), np.asarray(xq @ wq), rtol=1e-6)


def test_embedding_scales():
    table = jnp.asarray(np.random.default_rng(4).standard_normal((10, 6)), jnp.float32)
    toks = jnp.asarray([[1, 2], [3, 1]], jnp.int32)

    def f(table):
        return jnp.sum(ops.scaled_embedding(table, toks, jnp.float32(2.0), jnp.float32(3.0)))

    y = f(table)
    assert np.allclose(float(y), 2.0 * float(jnp.sum(table[toks])), rtol=1e-6)
    g = jax.grad(f)(table)
    # token 1 appears twice: grad 2*3; tokens 2,3 once: grad 3; others 0
    assert np.allclose(np.asarray(g)[1], 6.0)
    assert np.allclose(np.asarray(g)[2], 3.0)
    assert np.allclose(np.asarray(g)[0], 0.0)


def test_rmsnorm_unit_output():
    x = jnp.asarray(np.random.default_rng(5).standard_normal((64, 128)) * 37.0, jnp.float32)
    y = ops.rmsnorm(x)
    rms_rows = np.sqrt(np.mean(np.square(np.asarray(y)), axis=-1))
    assert np.allclose(rms_rows, 1.0, atol=1e-3)
    # 0-homogeneous: scaling the input leaves the output unchanged
    y2 = ops.rmsnorm(x * 1000.0)
    assert np.allclose(np.asarray(y), np.asarray(y2), atol=1e-4)


def test_rope_is_isometry():
    x = jnp.asarray(np.random.default_rng(6).standard_normal((2, 16, 4, 8)), jnp.float32)
    y = ops.rope(x)
    # pairwise rotations preserve per-position norms
    n_in = np.linalg.norm(np.asarray(x), axis=-1)
    n_out = np.linalg.norm(np.asarray(y), axis=-1)
    assert np.allclose(n_in, n_out, rtol=1e-5)
    # position 0 is unrotated
    assert np.allclose(np.asarray(y)[:, 0], np.asarray(x)[:, 0], atol=1e-6)


def test_attention_causal():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((1, 8, 2, 4)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 8, 2, 4)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 8, 2, 4)), jnp.float32)
    out = ops.attention(q, k, v, jnp.float32(0.25), jnp.float32(1.0))
    # changing future keys/values must not change earlier outputs
    k2 = k.at[:, 5:].set(0.0)
    v2 = v.at[:, 5:].set(99.0)
    out2 = ops.attention(q, k2, v2, jnp.float32(0.25), jnp.float32(1.0))
    assert np.allclose(np.asarray(out)[:, :5], np.asarray(out2)[:, :5], rtol=1e-5)
    assert not np.allclose(np.asarray(out)[:, 6:], np.asarray(out2)[:, 6:])


def test_softmax_xent_matches_plain_ce():
    rng = np.random.default_rng(8)
    logits = jnp.asarray(rng.standard_normal((3, 5, 11)), jnp.float32)
    tgt = jnp.asarray(rng.integers(0, 11, (3, 5)), jnp.int32)
    loss = ops.softmax_xent(logits, tgt, jnp.float32(1.0), jnp.float32(1.0))
    lp = jax.nn.log_softmax(np.asarray(logits), axis=-1)
    expect = -np.mean([lp[i, j, tgt[i, j]] for i in range(3) for j in range(5)])
    assert np.allclose(float(loss), expect, rtol=1e-5)


def test_softmax_xent_beta_scales_grad_only():
    rng = np.random.default_rng(9)
    logits = jnp.asarray(rng.standard_normal((2, 3, 7)), jnp.float32)
    tgt = jnp.asarray(rng.integers(0, 7, (2, 3)), jnp.int32)

    def f(beta):
        return jax.grad(
            lambda z: ops.softmax_xent(z, tgt, jnp.float32(1.0), jnp.float32(beta))
        )(logits)

    g1, g4 = f(1.0), f(4.0)
    assert np.allclose(np.asarray(g4), 4.0 * np.asarray(g1), rtol=1e-5)
    # loss value itself unaffected by beta
    l1 = ops.softmax_xent(logits, tgt, jnp.float32(1.0), jnp.float32(1.0))
    l4 = ops.softmax_xent(logits, tgt, jnp.float32(1.0), jnp.float32(4.0))
    assert np.allclose(float(l1), float(l4))


def test_residual_add_is_linear_mix():
    a, b = jnp.float32(0.6), jnp.float32(0.8)
    x = jnp.asarray([1.0, 2.0], jnp.float32)
    y = jnp.asarray([10.0, 20.0], jnp.float32)
    out = ops.residual_add(x, y, a, b)
    assert np.allclose(np.asarray(out), [0.6 * 1 + 0.8 * 10, 0.6 * 2 + 0.8 * 20])


@pytest.mark.parametrize("alpha,lo,hi", [(1e-6, 1.9, 2.1), (1e6, 1.39, 1.45)])
def test_gated_silu_empirical_scale_model(alpha, lo, hi):
    """The Rust-side scale model (Table 8) must match the op's actual
    output std under unit-Gaussian inputs at the extremes."""
    rng = np.random.default_rng(10)
    x_in = jnp.asarray(rng.standard_normal(200_000), jnp.float32)
    x_gate = jnp.asarray(rng.standard_normal(200_000), jnp.float32)
    y = ops.gated_silu(x_in, x_gate, jnp.float32(alpha), jnp.float32(1.0))
    mult = 1.0 / float(jnp.std(y))
    assert lo < mult < hi, mult
