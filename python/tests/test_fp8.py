"""L1 quantizer correctness: kernel vs oracle vs ml_dtypes.

The oracle (`ref.quantize_ref`) is pinned bit-exactly to ml_dtypes' cast
semantics on the in-range domain; the Pallas kernel must match the oracle
bit-exactly everywhere (including saturation, which deliberately differs
from ml_dtypes' overflow-to-NaN/inf — torch._scaled_mm saturates).
"""

import numpy as np
import jax.numpy as jnp
import ml_dtypes
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fp8, ref

ML = {
    "e4m3": ml_dtypes.float8_e4m3fn,
    "e5m2": ml_dtypes.float8_e5m2,
    "fp16": np.float16,
    "bf16": ml_dtypes.bfloat16,
}


def wide_floats(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n) * np.exp2(rng.uniform(-40, 40, size=n))
    return x.astype(np.float32)


@pytest.mark.parametrize("fmt_name", list(ML))
def test_ref_matches_ml_dtypes_in_range(fmt_name):
    fmt = ref.FORMATS[fmt_name]
    x = wide_floats(200_000, 0)
    q = np.asarray(ref.quantize_ref(x, fmt))
    with np.errstate(over="ignore"):
        md = x.astype(ML[fmt_name]).astype(np.float32)
    mask = np.abs(x) <= fmt.max_value
    assert np.array_equal(q[mask], md[mask])


@pytest.mark.parametrize("fmt_name", list(ML))
def test_kernel_matches_ref(fmt_name):
    x = wide_floats(64 * 256, 1).reshape(64, 256)
    q_ref = np.asarray(ref.quantize_ref(x, ref.FORMATS[fmt_name]))
    q_k = np.asarray(fp8.quantize(jnp.asarray(x), fmt_name))
    assert np.array_equal(q_k, q_ref)


def test_tiled_kernel_matches_full_block():
    x = wide_floats(100 * 300, 2).reshape(100, 300)  # non-divisible shape
    a = np.asarray(fp8.quantize(jnp.asarray(x), "e4m3", tiled=False))
    b = np.asarray(fp8.quantize(jnp.asarray(x), "e4m3", tiled=True))
    assert np.array_equal(a, b)


def test_saturation_and_specials():
    fmt = ref.E4M3
    x = np.array([1e9, -1e9, 448.0, 449.0, 0.0, -0.0, 2**-9, 2**-11], np.float32)
    q = np.asarray(ref.quantize_ref(x, fmt))
    assert q[0] == 448.0 and q[1] == -448.0
    assert q[2] == 448.0
    assert q[4] == 0.0 and np.signbit(q[5])
    assert q[6] == 2**-9  # min subnormal preserved
    assert q[7] == 0.0  # below half min-subnormal -> zero


def test_idempotent():
    x = wide_floats(10_000, 3)
    for fmt in (ref.E4M3, ref.E5M2, ref.BF16):
        q1 = np.asarray(ref.quantize_ref(x, fmt))
        q2 = np.asarray(ref.quantize_ref(q1, fmt))
        assert np.array_equal(q1, q2), fmt.name


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 40),
    cols=st.integers(1, 200),
    log_scale=st.floats(-20, 20),
    seed=st.integers(0, 2**31 - 1),
    fmt_name=st.sampled_from(["e4m3", "e5m2"]),
)
def test_kernel_shape_dtype_sweep(rows, cols, log_scale, seed, fmt_name):
    """Hypothesis sweep over shapes/scales: kernel == oracle, always."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((rows, cols)) * 2.0**log_scale).astype(np.float32)
    q_ref = np.asarray(ref.quantize_ref(x, ref.FORMATS[fmt_name]))
    q_k = np.asarray(fp8.quantize(jnp.asarray(x), fmt_name))
    assert np.array_equal(q_k, q_ref)
    # quantization error bounded by half a ulp of the magnitude
    fmtf = ref.FORMATS[fmt_name]
    in_range = np.abs(x) <= fmtf.max_value
    rel = np.abs(q_ref - x)[in_range]
    bound = np.maximum(np.abs(x[in_range]) * 2.0 ** (-fmtf.mant_bits) / 1.99,
                       fmtf.min_subnormal)
    assert np.all(rel <= bound)


def test_monotone():
    """Quantization preserves (non-strict) order."""
    x = np.sort(wide_floats(50_000, 4))
    q = np.asarray(ref.quantize_ref(x, ref.E4M3))
    assert np.all(np.diff(q) >= 0)


def test_quantize_masked_blend():
    x = wide_floats(1000, 5).reshape(10, 100)
    on = np.asarray(fp8.quantize_masked(jnp.asarray(x), jnp.float32(1.0), "e4m3"))
    off = np.asarray(fp8.quantize_masked(jnp.asarray(x), jnp.float32(0.0), "e4m3"))
    assert np.array_equal(on, np.asarray(ref.quantize_ref(x, ref.E4M3)))
    assert np.array_equal(off, x)


def test_vmem_budget():
    assert fp8.vmem_bytes() < 16 * 2**20  # fits VMEM comfortably
