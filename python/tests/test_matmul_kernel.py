"""L1 tiled matmul kernel vs oracle, with hypothesis shape sweeps."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref


def test_full_block_matches_ref():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((48, 96)).astype(np.float32)
    w = rng.standard_normal((96, 32)).astype(np.float32)
    got = np.asarray(matmul.u_matmul(jnp.asarray(x), jnp.asarray(w), 0.125, tiled=False))
    want = np.asarray(ref.scaled_matmul_ref(x, w, 0.125))
    assert np.allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 150),
    k=st.integers(1, 150),
    n=st.integers(1, 150),
    bm=st.sampled_from([16, 32, 64]),
    bk=st.sampled_from([16, 32, 64]),
    bn=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 1000),
)
def test_tiled_matches_ref_any_shape(m, k, n, bm, bk, bn, seed):
    """Grid tiling with padding must be exact for non-divisible shapes."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(
        matmul.u_matmul(jnp.asarray(x), jnp.asarray(w), 1.0, bm=bm, bn=bn, bk=bk)
    )
    want = np.asarray(ref.scaled_matmul_ref(x, w, 1.0))
    assert got.shape == (m, n)
    assert np.allclose(got, want, rtol=1e-4, atol=1e-4)


def test_unit_scaling_factor_normalizes_output():
    """With the Table 8 factor 1/sqrt(fan-in), unit inputs give ~unit out."""
    rng = np.random.default_rng(1)
    k = 512
    x = rng.standard_normal((256, k)).astype(np.float32)
    w = rng.standard_normal((k, 256)).astype(np.float32)
    y = np.asarray(matmul.u_matmul(jnp.asarray(x), jnp.asarray(w), 1.0 / np.sqrt(k)))
    assert abs(y.std() - 1.0) < 0.05


def test_mxu_stats_structural():
    s = matmul.mxu_stats(256, 256, 256)
    assert s["vmem_bytes"] < 16 * 2**20
    assert s["mxu_pass_utilization"] == 1.0
    s = matmul.mxu_stats(64, 64, 64, bm=64, bn=64, bk=64)
    assert s["mxu_pass_utilization"] == 0.125  # (64/128)^3
