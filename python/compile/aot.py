"""AOT pipeline: lower the L2 graphs to XLA HLO *text* artifacts.

Python runs only here (``make artifacts``); the Rust coordinator loads the
text with ``HloModuleProto::from_text_file`` and never touches Python at
runtime.  HLO text (NOT ``lowered.compile()``/``.serialize()``) is the
interchange format because jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Per spec we emit into ``artifacts/<spec-name>/``:
    init.hlo.txt      init(seed, init_std) -> state_ext
    step.hlo.txt      step(state_ext, tokens, scales, lr_scale, hyp, qmask)
                      -> state_ext'        (single-array root: the Rust
                      runtime chains the output buffer straight back in
                      with execute_b, reading only the telemetry tail)
    eval.hlo.txt      evalf(state_ext, tokens, scales, qmask) -> f32[1+n_rms]
    manifest.json     layout contract (specs.layout)

Plus standalone L1 kernel artifacts under ``artifacts/kernels/`` used by
the Rust cross-check tests (software codec vs Pallas quantizer).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import make_eval, make_init, make_step
from .specs import Spec, layout

# ---------------------------------------------------------------------------
# spec matrix (DESIGN.md §7)
# ---------------------------------------------------------------------------

WIDTH_SWEEP = [32, 64, 128, 256]
DEPTH_SWEEP = [2, 8]
BATCH_SWEEP = [8, 32]

DEFAULT_SPECS = (
    [Spec(width=w, depth=4, batch=16) for w in WIDTH_SWEEP]
    + [Spec(width=64, depth=d, batch=16) for d in DEPTH_SWEEP]
    + [Spec(width=64, depth=4, batch=b) for b in BATCH_SWEEP]
    + [Spec(width=w, depth=4, batch=16, trainable_norms=True) for w in (32, 64, 128)]
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (single-array root)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_spec(spec: Spec, out_dir: str, force: bool = False):
    man = layout(spec)
    d = os.path.join(out_dir, spec.name)
    man_path = os.path.join(d, "manifest.json")
    stamp = source_stamp()
    if not force and os.path.exists(man_path):
        try:
            with open(man_path) as f:
                if json.load(f).get("source_stamp") == stamp:
                    print(f"  {spec.name}: up to date")
                    return
        except (json.JSONDecodeError, OSError):
            pass
    print(f"building {spec.name} ...")
    n_t = len(man["tensors"])
    s_ext = man["state_ext_len"]

    init = make_init(spec)
    _write(
        os.path.join(d, "init.hlo.txt"),
        to_hlo_text(jax.jit(init).lower(i32(), f32(n_t))),
    )

    step = make_step(spec)
    _write(
        os.path.join(d, "step.hlo.txt"),
        to_hlo_text(
            jax.jit(step).lower(
                f32(s_ext),
                i32(spec.batch, spec.seq + 1),
                f32(man["n_scale_sites"]),
                f32(n_t),
                f32(8),
                f32(man["n_quant_sites"]),
            )
        ),
    )

    evalf = make_eval(spec)
    _write(
        os.path.join(d, "eval.hlo.txt"),
        to_hlo_text(
            jax.jit(evalf).lower(
                f32(s_ext),
                i32(spec.batch, spec.seq + 1),
                f32(man["n_scale_sites"]),
                f32(man["n_quant_sites"]),
            )
        ),
    )

    # telemetry-tail extractor: the 0.5.1 CPU PJRT plugin lacks
    # CopyRawToHost, so the runtime reads [loss | rms] by running this
    # trivial slice on the device-resident state instead.
    lo = man["loss_offset"]

    def tail(state_ext):
        return jax.lax.slice(state_ext, (lo,), (s_ext,))

    _write(os.path.join(d, "tail.hlo.txt"), to_hlo_text(jax.jit(tail).lower(f32(s_ext))))

    man["source_stamp"] = stamp
    with open(man_path, "w") as f:
        json.dump(man, f, indent=1)


def build_kernel_artifacts(out_dir: str):
    """Standalone L1 kernels for the Rust cross-check integration tests."""
    from .kernels.fp8 import quantize
    from .kernels.matmul import u_matmul

    d = os.path.join(out_dir, "kernels")
    for fmt in ("e4m3", "e5m2", "bf16", "fp16"):
        fn = lambda x: quantize(x, fmt, tiled=True)  # noqa: E731
        _write(
            os.path.join(d, f"quantize_{fmt}.hlo.txt"),
            to_hlo_text(jax.jit(fn).lower(f32(128, 128))),
        )
    mm = lambda x, w: u_matmul(x, w, out_scale=0.0883883476, bm=64, bn=64, bk=64)  # noqa: E731  (1/sqrt(128))
    _write(
        os.path.join(d, "u_matmul_128.hlo.txt"),
        to_hlo_text(jax.jit(mm).lower(f32(128, 128), f32(128, 128))),
    )


def source_stamp() -> str:
    """Hash of the compile-path sources: artifacts rebuild when L1/L2 change."""
    h = hashlib.sha256()
    root = os.path.dirname(__file__)
    for dirpath, _, files in sorted(os.walk(root)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(dirpath, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default=None, help="build a single spec by name")
    args = ap.parse_args()

    specs = DEFAULT_SPECS
    if args.only:
        specs = [s for s in specs if s.name == args.only]
        if not specs:
            sys.exit(f"unknown spec {args.only}")
    for spec in specs:
        build_spec(spec, args.out, force=args.force)
    build_kernel_artifacts(args.out)
    print("artifacts complete")


if __name__ == "__main__":
    main()
