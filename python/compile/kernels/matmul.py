"""L1 Pallas kernel: tiled unit-scaled matmul.

Computes ``(x @ w) * out_scale`` with MXU-shaped tiles: the grid walks
(M/bm, N/bn) output tiles and accumulates over K in bk-sized slabs held in
VMEM, i.e. the BlockSpec expresses the HBM↔VMEM schedule that the paper's
GPU kernels express with threadblocks (DESIGN.md §3).  The static
``out_scale`` is Unit Scaling's 1/sqrt(fan-in) factor — applied once per
output tile, which is why static scaling is (near) free (paper Fig 24 /
Appendix K).

On CPU everything runs under ``interpret=True``; the train-step artifacts
use the single-block fast path (bm=M, bn=N, bk=K) which lowers to one XLA
dot, while the tiled path is exercised by tests and the standalone kernel
artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles (128x128 systolic array, f32 accumulation).
BM, BN, BK = 128, 128, 128


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int, out_scale: float):
    """One (i, j, k) grid step: acc += x_tile @ w_tile; flush at k end."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * out_scale).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("out_scale", "bm", "bn", "bk", "tiled")
)
def u_matmul(x, w, out_scale: float = 1.0, bm=BM, bn=BN, bk=BK, tiled=True):
    """Unit-scaled matmul kernel. x: f32[M,K], w: f32[K,N] -> f32[M,N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    if not tiled:
        bm, bn, bk = m, n, k
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pn)))
    n_k = xp.shape[1] // bk
    out = pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k, out_scale=out_scale),
        grid=(xp.shape[0] // bm, wp.shape[1] // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        scratch_shapes=[pltpu_scratch((bm, bn))],
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def pltpu_scratch(shape):
    """VMEM f32 scratch accumulator (works in interpret mode on CPU)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def mxu_stats(m: int, n: int, k: int, bm=BM, bn=BN, bk=BK) -> dict:
    """Analytic TPU estimates for DESIGN.md §9 (interpret mode gives no
    hardware timing): VMEM footprint per grid step and MXU utilization
    (fraction of each 128x128x128 MXU pass doing useful work)."""
    vmem = (bm * bk + bk * bn + 2 * bm * bn) * 4
    util = (min(bm, m) / bm) * (min(bn, n) / bn) * (min(bk, k) / bk)
    eff_m, eff_n, eff_k = min(bm, 128), min(bn, 128), min(bk, 128)
    mxu = (eff_m / 128) * (eff_n / 128) * (eff_k / 128)
    return {
        "vmem_bytes": vmem,
        "vmem_frac_of_16MiB": vmem / (16 * 2**20),
        "tile_fill": util,
        "mxu_pass_utilization": mxu,
    }
