"""L1 Pallas kernel: quantize-to-FP8-grid (the numeric-format hot-spot).

The kernel rounds an f32 tensor onto the representable grid of a target
low-precision format (E4M3FN / E5M2 / FP16 / BF16) with round-to-nearest-
even, saturation-to-max, and exact subnormal handling, while keeping the
carrier dtype f32 (FP8 arithmetic is *simulated* on this CPU testbed —
see DESIGN.md §3 Hardware adaptation).

TPU mapping: the kernel is written with row-major BlockSpec tiles whose
trailing dimension is a multiple of 128 (lane width) and whose leading
dimension is a multiple of 8 (sublane), so each block is one VMEM-resident
VPU pass: bitcast → shift/mask (exponent extract) → mul/round/mul → clamp.
``interpret=True`` is mandatory on CPU (Mosaic custom-calls cannot run on
the CPU PJRT plugin); the same code lowers to Mosaic on a real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import FORMATS, FloatFormat, pow2_exact

# Block shape used when tiling is enabled. (8, 128) is the TPU float32
# VREG shape; we use a few VREGs per block to amortize grid overhead.
TILE_ROWS = 64
TILE_COLS = 128


def _quantize_block(x, fmt: FloatFormat):
    """Elementwise grid rounding; shared by the kernel body and fallback."""
    ax = jnp.abs(x)
    bits = jax.lax.bitcast_convert_type(ax, jnp.int32)
    exp = ((bits >> 23) & 0xFF) - 127
    exp = jnp.maximum(exp, fmt.min_normal_exp)
    ulp = pow2_exact(exp - fmt.mant_bits)
    q = jnp.round(x / ulp) * ulp
    q = jnp.clip(q, -fmt.max_value, fmt.max_value)
    return jnp.where(ax == 0, x, q).astype(jnp.float32)


def _kernel(x_ref, o_ref, *, fmt: FloatFormat):
    o_ref[...] = _quantize_block(x_ref[...], fmt)


@functools.partial(jax.jit, static_argnames=("fmt_name", "tiled"))
def quantize(x, fmt_name: str = "e4m3", tiled: bool = False):
    """Quantize ``x`` onto the grid of ``fmt_name`` via the Pallas kernel.

    ``tiled=False`` uses a single full-array block (the fast path inside
    the AOT-compiled train step on CPU); ``tiled=True`` exercises the real
    (TILE_ROWS, TILE_COLS) VMEM tiling used for the TPU estimate and for
    kernel-level tests.
    """
    fmt = FORMATS[fmt_name]
    orig_shape = x.shape
    x2 = x.reshape((-1, orig_shape[-1])) if x.ndim != 2 else x
    if not tiled:
        out = pl.pallas_call(
            functools.partial(_kernel, fmt=fmt),
            out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
            interpret=True,
        )(x2)
        return out.reshape(orig_shape)

    rows, cols = x2.shape
    tr, tc = min(TILE_ROWS, rows), min(TILE_COLS, cols)
    # pad so the grid divides evenly (pallas interpret requires it)
    pr, pc = (-rows) % tr, (-cols) % tc
    xp = jnp.pad(x2, ((0, pr), (0, pc)))
    out = pl.pallas_call(
        functools.partial(_kernel, fmt=fmt),
        grid=(xp.shape[0] // tr, xp.shape[1] // tc),
        in_specs=[pl.BlockSpec((tr, tc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(xp)
    return out[:rows, :cols].reshape(orig_shape)


def quantize_masked(x, qflag, fmt_name: str):
    """Runtime-maskable quantization: q = qflag*Q(x) + (1-qflag)*x.

    ``qflag`` is a traced f32 scalar in {0,1} from the ``qmask`` input, so
    a single compiled artifact serves both full-precision and FP8-sim
    training (DESIGN.md §2, runtime scale hooks).
    """
    return qflag * quantize(x, fmt_name) + (1.0 - qflag) * x


def vmem_bytes(tile_rows: int = TILE_ROWS, tile_cols: int = TILE_COLS) -> int:
    """VMEM footprint estimate for one grid step (input + output tile)."""
    return 2 * tile_rows * tile_cols * 4
