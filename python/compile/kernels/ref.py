"""Pure-jnp oracles for the Pallas kernels (L1 correctness reference).

``quantize_ref`` implements round-to-nearest-even quantization onto the
exact representable grid of a low-precision float format, with
saturation-to-max (fn-style, matching torch._scaled_mm / E4M3FN semantics)
and correct subnormal handling.  It is validated bit-exactly against
``ml_dtypes`` in python/tests/test_fp8.py and against the Rust software
codecs in rust/tests/ (via the standalone kernel artifacts).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """A binary floating-point format (sign + exponent + mantissa)."""

    name: str
    exp_bits: int
    mant_bits: int
    # fn ("finite-only") formats repurpose the inf encodings as extra
    # finite range (E4M3FN): max = (2 - 2*2^-m) * 2^emax = 1.75 * 2^8 = 448
    finite_only: bool = False

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def min_normal_exp(self) -> int:
        return 1 - self.bias

    @property
    def max_exp(self) -> int:
        # fn formats use the all-ones exponent for normal numbers too
        return ((1 << self.exp_bits) - 1) - self.bias - (0 if self.finite_only else 1)

    @property
    def max_value(self) -> float:
        m = self.mant_bits
        frac = 2.0 - 2.0 ** (-m)
        if self.finite_only:
            # the top mantissa pattern is NaN, so max mantissa is one ulp lower
            frac = 2.0 - 2.0 ** (-m) * 2.0
        return frac * 2.0 ** self.max_exp

    @property
    def min_normal(self) -> float:
        return 2.0 ** self.min_normal_exp

    @property
    def min_subnormal(self) -> float:
        return 2.0 ** (self.min_normal_exp - self.mant_bits)


E4M3 = FloatFormat("e4m3", exp_bits=4, mant_bits=3, finite_only=True)
E5M2 = FloatFormat("e5m2", exp_bits=5, mant_bits=2)
FP16 = FloatFormat("fp16", exp_bits=5, mant_bits=10)
BF16 = FloatFormat("bf16", exp_bits=8, mant_bits=7)

FORMATS = {f.name: f for f in (E4M3, E5M2, FP16, BF16)}


def pow2_exact(e):
    """Exact f32 power of two from an integer exponent tensor.

    ``jnp.exp2`` on XLA CPU is only faithfully rounded (computed via exp),
    which breaks bit-exactness of the quantization grid; constructing the
    bit pattern directly is exact.  Exponents below -126 are handled by a
    two-factor product whose result is an exactly-representable subnormal.
    """
    import jax

    e = jnp.asarray(e, jnp.int32)
    e1 = jnp.maximum(e, -126)
    hi = jax.lax.bitcast_convert_type((e1 + 127) << 23, jnp.float32)
    lo = jax.lax.bitcast_convert_type(((e - e1) + 127) << 23, jnp.float32)
    return hi * lo


def quantize_ref(x, fmt: FloatFormat):
    """Round ``x`` (f32) to the representable grid of ``fmt`` (RTNE).

    Saturating cast: values beyond max_value clamp to ±max_value (this is
    the E4M3FN convention and what the paper's .to(float8) cast does under
    torch._scaled_mm).  Zeros and signs are preserved; values that would
    underflow below half the smallest subnormal round to zero through the
    ordinary grid rounding.
    """
    import jax

    x = jnp.asarray(x, jnp.float32)
    ax = jnp.abs(x)
    # Exact exponent extraction from the f32 bit pattern (no libm error):
    # biased exponent bits, clamped into [min_normal_exp, inf) so that all
    # subnormals share the min-normal exponent (=> fixed-point grid there).
    bits = jax.lax.bitcast_convert_type(ax, jnp.int32)
    exp = ((bits >> 23) & 0xFF) - 127
    exp = jnp.maximum(exp, fmt.min_normal_exp)
    # Grid spacing at this exponent; jnp.round is round-half-to-even.
    ulp = pow2_exact(exp - fmt.mant_bits)
    q = jnp.round(x / ulp) * ulp
    q = jnp.clip(q, -fmt.max_value, fmt.max_value)
    return jnp.where(ax == 0, x, q).astype(jnp.float32)


def scaled_matmul_ref(x, w, out_scale):
    """f32 oracle for the unit-scaled matmul kernel: (x @ w) * out_scale."""
    return (
        jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32)) * out_scale
    ).astype(jnp.float32)


def quant_matmul_ref(x, w, out_scale, fmt_in=E4M3):
    """Oracle for the fp8-simulated matmul: quantize inputs, matmul in f32."""
    return scaled_matmul_ref(quantize_ref(x, fmt_in), quantize_ref(w, fmt_in), out_scale)
