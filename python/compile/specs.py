"""Model specs, tensor tables, scale-site tables and RMS-site tables.

This module is the single source of truth for the contract between the
JAX compile layer (L2) and the Rust coordinator (L3).  Everything here is
serialized into ``manifest.json`` next to each HLO artifact; the Rust side
mirrors these layouts in ``rust/src/runtime/artifact.rs``.

Layout conventions
------------------
* All parameters and Adam moments are packed into one flat ``f32[S_ext]``
  "extended state" vector::

      [ params (P) | m (P) | v (P) | loss (1) | rms (n_rms) ]

  so a train step is state-in/state-out with a telemetry tail that the
  Rust runtime reads with a partial device-to-host copy.
* Every *scale site* in the graph reads a scalar from the runtime
  ``scales: f32[n_sites]`` input.  Matmul sites own three consecutive
  scalars (fwd-output, grad-input, grad-weight); unary/multiplier sites
  own one.
* Every quantization site owns one 0/1 flag in ``qmask: f32[n_qsites]``
  (x-input, weight, output-gradient per matmul site).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Tuple

HEAD_DIM = 16


@dataclasses.dataclass(frozen=True)
class Spec:
    """A compiled model shape. One artifact directory per Spec."""

    width: int
    depth: int
    batch: int
    seq: int = 64
    vocab: int = 256
    head_dim: int = HEAD_DIM
    ffn_ratio: float = 2.75  # Llama-style gated FFN ratio (Table 6)
    trainable_norms: bool = False  # Fig 2(a) TP5-style ablation

    @property
    def n_heads(self) -> int:
        assert self.width % self.head_dim == 0
        return self.width // self.head_dim

    @property
    def d_ffn(self) -> int:
        # round to a multiple of 8 for tidy tiling
        return int(self.width * self.ffn_ratio) // 8 * 8

    @property
    def name(self) -> str:
        tag = "_tn" if self.trainable_norms else ""
        return (
            f"w{self.width}_d{self.depth}_b{self.batch}"
            f"_t{self.seq}_v{self.vocab}{tag}"
        )


@dataclasses.dataclass(frozen=True)
class TensorInfo:
    name: str
    shape: Tuple[int, ...]
    kind: str  # "emb" | "hidden" | "out" | "norm"
    fan_in: int
    fan_out: int
    offset: int  # element offset into the params segment

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


def tensor_table(spec: Spec) -> List[TensorInfo]:
    """Parameter tensors in packing order.

    Weight-type classification follows Table 1: *input* weights have only
    fan-out ∝ width (embedding), *hidden* both, *output* only fan-in ∝
    width (decoder head).
    """
    w, d_ffn = spec.width, spec.d_ffn
    infos: List[TensorInfo] = []
    off = 0

    def add(name: str, shape: Tuple[int, ...], kind: str, fan_in: int, fan_out: int):
        nonlocal off
        infos.append(TensorInfo(name, shape, kind, fan_in, fan_out, off))
        n = 1
        for s in shape:
            n *= s
        off += n

    add("emb", (spec.vocab, w), "emb", spec.vocab, w)
    for l in range(spec.depth):
        p = f"l{l}."
        if spec.trainable_norms:
            add(p + "attn_norm.g", (w,), "norm", w, w)
        add(p + "attn.q", (w, w), "hidden", w, w)
        add(p + "attn.k", (w, w), "hidden", w, w)
        add(p + "attn.v", (w, w), "hidden", w, w)
        add(p + "attn.o", (w, w), "hidden", w, w)
        if spec.trainable_norms:
            add(p + "ffn_norm.g", (w,), "norm", w, w)
        add(p + "ffn.gate", (w, d_ffn), "hidden", w, d_ffn)
        add(p + "ffn.up", (w, d_ffn), "hidden", w, d_ffn)
        add(p + "ffn.down", (d_ffn, w), "hidden", d_ffn, w)
    if spec.trainable_norms:
        add("final_norm.g", (w,), "norm", w, w)
    add("head", (w, spec.vocab), "out", w, spec.vocab)
    return infos


# ---------------------------------------------------------------------------
# Scale sites
# ---------------------------------------------------------------------------

MATMUL_SUFFIXES = (".out", ".gx", ".gw")


def scale_sites(spec: Spec) -> Dict[str, int]:
    """Ordered map from scale-site name to index in the scales vector.

    Matmul sites contribute three entries ``<site>.out/.gx/.gw``; scalar
    multiplier sites contribute one entry under their own name.
    """
    sites: Dict[str, int] = {}

    def mm(site: str):
        for sfx in MATMUL_SUFFIXES:
            sites[site + sfx] = len(sites)

    def one(site: str):
        sites[site] = len(sites)

    one("emb.scale")  # forward multiplier on embedding output
    one("emb.gw")  # backward scale on the embedding-table gradient
    for l in range(spec.depth):
        p = f"l{l}."
        for name in ("attn.q", "attn.k", "attn.v", "attn.o"):
            mm(p + name)
        one(p + "attn.logit_mult")  # alpha_attn_softmax * (1/d or 1/sqrt d)
        one(p + "attn.out_scale")  # unit-scaling log-interpolate factor
        for name in ("ffn.gate", "ffn.up", "ffn.down"):
            mm(p + name)
        one(p + "ffn.act_alpha")  # alpha_ffn-act inside the sigmoid
        one(p + "ffn.act_scale")  # unit-scaling gated-silu factor
        one(p + "res.attn.a")
        one(p + "res.attn.b")
        one(p + "res.ffn.a")
        one(p + "res.ffn.b")
    mm("head")
    one("loss.alpha")  # alpha_loss_softmax pre-multiplier on logits
    one("loss.beta")  # backward-only scale on the xent gradient
    return sites


def quant_sites(spec: Spec) -> Dict[str, int]:
    """0/1 flags: quantize x-input / weight to E4M3, out-gradient to E5M2."""
    sites: Dict[str, int] = {}
    names = ["l%d.%s" % (l, n) for l in range(spec.depth)
             for n in ("attn.q", "attn.k", "attn.v", "attn.o",
                       "ffn.gate", "ffn.up", "ffn.down")]
    names.append("head")
    for site in names:
        for sfx in (".qx", ".qw", ".qg"):
            sites[site + sfx] = len(sites)
    return sites


def rms_sites(spec: Spec) -> List[str]:
    """Instrumented RMS telemetry, in tail order.

    act.*    — matmul input activations (Fig 6 / Fig 19)
    attn_out.* — raw attention-block output (Fig 25)
    skip.*   — residual stream after each block (Fig 25 / App. L)
    w.*      — weight RMS per tensor (Fig 6 right)
    g.*      — parameter-gradient RMS per tensor (Fig 19 proxy)
    """
    names: List[str] = []
    for l in range(spec.depth):
        p = f"l{l}."
        names += [f"act.{p}qkv_in", f"act.{p}o_in", f"act.{p}ffn_in",
                  f"act.{p}down_in", f"attn_out.{p}raw", f"skip.{p}post"]
    names.append("act.head_in")
    for t in tensor_table(spec):
        names.append("w." + t.name)
    for t in tensor_table(spec):
        names.append("g." + t.name)
    return names


def layout(spec: Spec) -> dict:
    """Full manifest dict (serialized to manifest.json by aot.py)."""
    tensors = tensor_table(spec)
    n_params = sum(t.size for t in tensors)
    rms = rms_sites(spec)
    sites = scale_sites(spec)
    qs = quant_sites(spec)
    return {
        "spec": dataclasses.asdict(spec),
        "name": spec.name,
        "n_heads": spec.n_heads,
        "d_ffn": spec.d_ffn,
        "tensors": [
            {
                "name": t.name,
                "shape": list(t.shape),
                "kind": t.kind,
                "fan_in": t.fan_in,
                "fan_out": t.fan_out,
                "offset": t.offset,
                "size": t.size,
            }
            for t in tensors
        ],
        "n_params": n_params,
        "state_ext_len": 3 * n_params + 1 + len(rms),
        "loss_offset": 3 * n_params,
        "rms_offset": 3 * n_params + 1,
        "scale_sites": sites,
        "n_scale_sites": len(sites),
        "quant_sites": qs,
        "n_quant_sites": len(qs),
        "rms_sites": rms,
        "hyp_layout": [
            "lr", "wd_coupled", "wd_indep", "beta1", "beta2",
            "eps", "bc1", "bc2",
        ],
        "io": {
            "init": ["seed:i32[]", "init_std:f32[n_tensors]"],
            "step": [
                "state_ext:f32[state_ext_len]",
                "tokens:i32[batch,seq+1]",
                "scales:f32[n_scale_sites]",
                "lr_scale:f32[n_tensors]",
                "hyp:f32[8]",
                "qmask:f32[n_quant_sites]",
            ],
            "evalf": [
                "state_ext:f32[state_ext_len]",
                "tokens:i32[batch,seq+1]",
                "scales:f32[n_scale_sites]",
                "qmask:f32[n_quant_sites]",
            ],
        },
    }


def dumps(spec: Spec) -> str:
    return json.dumps(layout(spec), indent=1)
