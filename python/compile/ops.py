"""L2: scaled ops — the Unit Scaling / abc-parametrization hook library.

Every op here takes its scaling factors as *traced scalars* (read from the
runtime ``scales`` vector), so a single compiled graph can realize SP, μP,
u-μP, or any HP point: the Rust coordinator (rust/src/parametrization/)
computes the numeric values per Table 1/2/8/11 and Appendix F/G of the
paper and feeds them in at execution time.

Scale-hook semantics (paper §2.3, Appendix B/H):
* ``scale_fb(x, fwd, bwd)``   — multiply by ``fwd`` in the forward pass and
  by ``bwd`` (instead of ``fwd``) in the backward pass.  Distinct fwd/bwd
  factors are only valid on cut edges (Appendix H); constrained sites pass
  ``fwd == bwd`` (u-μP uses the forward scale everywhere, Appendix B).
* ``scaled_matmul`` — three independent factors (output, grad-input,
  grad-weight; the weight-grad edge is always a cut edge), plus runtime
  0/1 quantization masks implementing the FP8 scheme of §4.2 / Fig 1(c)
  via the L1 Pallas quantizer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.fp8 import quantize_masked

# ---------------------------------------------------------------------------
# scale hooks
# ---------------------------------------------------------------------------


@jax.custom_vjp
def scale_fb(x, fwd, bwd):
    return x * fwd


def _scale_fb_fwd(x, fwd, bwd):
    return x * fwd, (fwd, bwd)


def _scale_fb_bwd(res, g):
    fwd, bwd = res
    return g * bwd, jnp.zeros_like(fwd), jnp.zeros_like(bwd)


scale_fb.defvjp(_scale_fb_fwd, _scale_fb_bwd)


@jax.custom_vjp
def scaled_matmul(x, w, s_out, s_gx, s_gw, qx, qw, qg):
    """y = (Q?(x) @ Q?(w)) * s_out with independently scaled gradients.

    Forward inputs optionally quantize to E4M3; the incoming output
    gradient optionally quantizes to E5M2 (the paper's non-critical-matmul
    recipe, §4.2).  The backward matmuls consume the *quantized* operands,
    matching real FP8 tensor-core training.
    """
    xq = quantize_masked(x, qx, "e4m3")
    wq = quantize_masked(w, qw, "e4m3")
    return jnp.matmul(xq, wq) * s_out


def _smm_fwd(x, w, s_out, s_gx, s_gw, qx, qw, qg):
    xq = quantize_masked(x, qx, "e4m3")
    wq = quantize_masked(w, qw, "e4m3")
    y = jnp.matmul(xq, wq) * s_out
    return y, (xq, wq, s_gx, s_gw, qg)


def _smm_bwd(res, g):
    xq, wq, s_gx, s_gw, qg = res
    gq = quantize_masked(g, qg, "e5m2")
    gx = jnp.matmul(gq, wq.T) * s_gx
    # contract away all leading (batch/seq) axes of x against g
    lead = tuple(range(xq.ndim - 1))
    gw = jnp.tensordot(xq, gq, axes=(lead, lead)) * s_gw
    z = jnp.zeros((), jnp.float32)
    return gx, gw, z, z, z, z, z, z


scaled_matmul.defvjp(_smm_fwd, _smm_bwd)


def scaled_embedding(table, tokens, s_fwd, s_gw):
    """Embedding lookup with fwd scale ``s_fwd`` and table-gradient scale
    ``s_gw``.  Applying the scale hook to the table *before* the gather is
    mathematically identical and keeps autodiff over the integer gather."""
    return scale_fb(table, s_fwd, s_gw)[tokens]


# ---------------------------------------------------------------------------
# normalization & position
# ---------------------------------------------------------------------------


def rms(x):
    """Paper's RMS = sqrt(sigma^2 + mu^2) = root-mean-square (Fig 6)."""
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def rmsnorm(x, gain=None, eps: float = 1e-6):
    """RMSNorm; non-trainable by default (Lingle's fix, §3.1).

    0-homogeneous, so it propagates no scale and needs no multiplier
    (Appendix G.1) and no Unit Scaling factor (Table 8).
    """
    y = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    if gain is not None:
        y = y * gain
    return y


def rope(x, theta: float = 10000.0):
    """Rotary position embeddings on [B, T, H, Dh]; no scale change
    (pairwise rotations are isometries — Table 8: alpha = beta = 1)."""
    b, t, h, d = x.shape
    half = d // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = jnp.arange(t, dtype=jnp.float32)[:, None] * inv[None, :]  # [T, half]
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# fused blocks (Table 8)
# ---------------------------------------------------------------------------


def attention(q, k, v, logit_mult, out_scale):
    """Causal scaled-dot-product attention.

    ``logit_mult`` is alpha_attn_softmax x (1/d_head for μP & u-μP,
    1/sqrt(d_head) for SP) — computed by the coordinator.  ``out_scale``
    is the Unit Scaling log-interpolate factor (Table 8), applied with
    fwd == bwd (constrained site).
    """
    b, t, h, d = q.shape
    logits = jnp.einsum("bthd,bshd->bhts", q, k) * logit_mult
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
    logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, v)
    return out * out_scale


def gated_silu(x_in, x_gate, act_alpha, out_scale):
    """SwiGLU gate: x_in ⊙ x_gate ⊙ sigmoid(alpha_ffn-act * x_gate),
    divided by the empirical Unit Scaling factor (Table 8)."""
    return x_in * x_gate * jax.nn.sigmoid(act_alpha * x_gate) * out_scale


def residual_add(branch, skip, a, b):
    """u-μP residual: a*f(x) + b*x with a^2+b^2=1 computed from the
    τ-scheme (Appendix G.2.2) by rust/src/parametrization/residual.rs.
    For μP/SP the coordinator instead sends the Table 2 'Residual' column
    multipliers with b=1."""
    return a * branch + b * skip


def softmax_xent(logits, targets, loss_alpha, loss_beta):
    """Unit-scaled cross-entropy (Table 8): pre-multiplier
    alpha_loss_softmax on the logits; backward-only gradient scale beta
    (= s/sqrt(s-1) under Unit Scaling, 1 otherwise). The *reported* loss
    is the true mean CE of the scaled-logit model."""
    z = scale_fb(logits * loss_alpha, jnp.float32(1.0), loss_beta)
    logp = jax.nn.log_softmax(z, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
