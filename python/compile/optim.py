"""L2: AdamW with per-element LR and *independent* weight decay.

The paper's stability fix (§3.1, following Wortsman et al.) uses the
independent form of AdamW: the decay term is NOT multiplied by the
learning rate.  Both forms are compiled in and runtime-selected via the
``hyp`` vector, so Fig 2's ablation (standard AdamW vs independent) needs
no recompilation:

    p' = p - lr_elem * (m_hat / (sqrt(v_hat) + eps) + wd_coupled * p)
           - wd_indep * wd_mask * p

with lr_elem = lr * lr_scale[tensor] broadcast per element (the
parametrization's C_W rule, Table 2) and bias-correction factors
bc1 = 1/(1-beta1^t), bc2 = 1/(1-beta2^t) supplied by the coordinator.
"""

from __future__ import annotations

import jax.numpy as jnp

# hyp vector layout (specs.layout()["hyp_layout"])
LR, WD_COUPLED, WD_INDEP, BETA1, BETA2, EPS, BC1, BC2 = range(8)


def adamw_update(p, g, m, v, lr_elem, wd_mask, hyp):
    """One fused AdamW step over the flat parameter vector."""
    lr = hyp[LR]
    beta1, beta2 = hyp[BETA1], hyp[BETA2]
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * jnp.square(g)
    m_hat = m2 * hyp[BC1]
    v_hat = v2 * hyp[BC2]
    update = m_hat / (jnp.sqrt(v_hat) + hyp[EPS])
    p2 = (
        p
        - lr * lr_elem * (update + hyp[WD_COUPLED] * wd_mask * p)
        - hyp[WD_INDEP] * wd_mask * p
    )
    return p2, m2, v2


def hyp_vector(lr, wd_coupled, wd_indep, beta1, beta2, eps, t):
    """Host-side helper mirrored by rust/src/train/schedule.rs."""
    bc1 = 1.0 / (1.0 - beta1**t)
    bc2 = 1.0 / (1.0 - beta2**t)
    return jnp.asarray(
        [lr, wd_coupled, wd_indep, beta1, beta2, eps, bc1, bc2], jnp.float32
    )
