"""L2: the Llama-style scaled transformer (paper §5.1, Table 5).

Architecture: PreNorm, RMSNorm (non-trainable by default), SwiGLU FFN
(ratio 2.75), RoPE, untied embeddings, causal LM loss.  Every scale site
reads from the runtime ``scales`` vector (see specs.scale_sites) and every
matmul owns three quantization flags in ``qmask`` — the compiled graph is
parametrization-agnostic (DESIGN.md §2).
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from . import ops
from .specs import Spec, TensorInfo, quant_sites, rms_sites, scale_sites, tensor_table


def unpack_params(flat, tensors: List[TensorInfo]) -> Dict[str, jnp.ndarray]:
    return {
        t.name: jax.lax.slice(flat, (t.offset,), (t.offset + t.size,)).reshape(t.shape)
        for t in tensors
    }


class Graph:
    """Binds a Spec's site tables to traced scales/qmask vectors."""

    def __init__(self, spec: Spec, scales, qmask):
        self.spec = spec
        self.scales = scales
        self.qmask = qmask
        self.sites = scale_sites(spec)
        self.qsites = quant_sites(spec)

    def s(self, name: str):
        return self.scales[self.sites[name]]

    def q(self, name: str):
        return self.qmask[self.qsites[name]]

    def mm(self, x, w, site: str):
        """Scaled (and maybe-quantized) matmul at a named site."""
        return ops.scaled_matmul(
            x, w,
            self.s(site + ".out"), self.s(site + ".gx"), self.s(site + ".gw"),
            self.q(site + ".qx"), self.q(site + ".qw"), self.q(site + ".qg"),
        )


def forward(spec: Spec, params: Dict[str, jnp.ndarray], tokens, scales, qmask):
    """Causal-LM forward. tokens: i32[B, T+1] (inputs || shifted targets).

    Returns (loss, rms_acts) where rms_acts maps the activation entries of
    specs.rms_sites to scalar RMS telemetry (Fig 6/19/25).
    """
    g = Graph(spec, scales, qmask)
    B, H, Dh = spec.batch, spec.n_heads, spec.head_dim
    T = spec.seq
    inp, tgt = tokens[:, :-1], tokens[:, 1:]

    acts: Dict[str, jnp.ndarray] = {}
    x = ops.scaled_embedding(params["emb"], inp, g.s("emb.scale"), g.s("emb.gw"))

    for l in range(spec.depth):
        p = f"l{l}."
        gain = params.get(p + "attn_norm.g")
        h = ops.rmsnorm(x, gain)
        acts[f"act.{p}qkv_in"] = ops.rms(h)
        q = g.mm(h, params[p + "attn.q"], p + "attn.q").reshape(B, T, H, Dh)
        k = g.mm(h, params[p + "attn.k"], p + "attn.k").reshape(B, T, H, Dh)
        v = g.mm(h, params[p + "attn.v"], p + "attn.v").reshape(B, T, H, Dh)
        q, k = ops.rope(q), ops.rope(k)
        a = ops.attention(
            q, k, v, g.s(p + "attn.logit_mult"), g.s(p + "attn.out_scale")
        ).reshape(B, T, H * Dh)
        acts[f"act.{p}o_in"] = ops.rms(a)
        a = g.mm(a, params[p + "attn.o"], p + "attn.o")
        acts[f"attn_out.{p}raw"] = ops.rms(a)
        x = ops.residual_add(a, x, g.s(p + "res.attn.a"), g.s(p + "res.attn.b"))

        gain = params.get(p + "ffn_norm.g")
        h = ops.rmsnorm(x, gain)
        acts[f"act.{p}ffn_in"] = ops.rms(h)
        x_gate = g.mm(h, params[p + "ffn.gate"], p + "ffn.gate")
        x_up = g.mm(h, params[p + "ffn.up"], p + "ffn.up")
        f = ops.gated_silu(
            x_up, x_gate, g.s(p + "ffn.act_alpha"), g.s(p + "ffn.act_scale")
        )
        acts[f"act.{p}down_in"] = ops.rms(f)
        f = g.mm(f, params[p + "ffn.down"], p + "ffn.down")
        x = ops.residual_add(f, x, g.s(p + "res.ffn.a"), g.s(p + "res.ffn.b"))
        acts[f"skip.{p}post"] = ops.rms(x)

    h = ops.rmsnorm(x, params.get("final_norm.g"))
    acts["act.head_in"] = ops.rms(h)
    logits = g.mm(h, params["head"], "head")
    loss = ops.softmax_xent(logits, tgt, g.s("loss.alpha"), g.s("loss.beta"))
    return loss, acts


def loss_fn(spec: Spec, flat_params, tokens, scales, qmask):
    tensors = tensor_table(spec)
    params = unpack_params(flat_params, tensors)
    return forward(spec, params, tokens, scales, qmask)


def rms_tail(spec: Spec, acts: Dict[str, jnp.ndarray], flat_params, flat_grads):
    """Assemble the telemetry tail in specs.rms_sites order."""
    tensors = {t.name: t for t in tensor_table(spec)}
    vals = []
    for name in rms_sites(spec):
        if name.startswith("w.") or name.startswith("g."):
            t = tensors[name[2:]]
            src = flat_params if name.startswith("w.") else flat_grads
            if src is None:
                vals.append(jnp.float32(0.0))
            else:
                seg = jax.lax.slice(src, (t.offset,), (t.offset + t.size,))
                vals.append(ops.rms(seg))
        else:
            vals.append(acts[name])
    return jnp.stack(vals)


def make_init(spec: Spec):
    """init(seed: i32[], init_std: f32[n_tensors]) -> state_ext f32[S_ext].

    Weights ~ N(0, init_std[i]^2); norm gains are *set to* init_std[i]
    (the coordinator passes 1.0).  Adam moments and the telemetry tail
    start at zero.
    """
    tensors = tensor_table(spec)
    n_params = sum(t.size for t in tensors)
    n_rms = len(rms_sites(spec))

    def init(seed, init_std):
        key = jax.random.PRNGKey(seed)
        parts = []
        for i, t in enumerate(tensors):
            if t.kind == "norm":
                parts.append(jnp.full((t.size,), 1.0, jnp.float32) * init_std[i])
            else:
                sub = jax.random.fold_in(key, i)
                parts.append(
                    jax.random.normal(sub, (t.size,), jnp.float32) * init_std[i]
                )
        flat = jnp.concatenate(parts)
        return jnp.concatenate(
            [flat, jnp.zeros((2 * n_params + 1 + n_rms,), jnp.float32)]
        )

    return init


def make_step(spec: Spec):
    """The fused train step (fwd + bwd + AdamW-independent + telemetry).

    state_ext layout: [params | m | v | loss | rms] (specs.layout).
    hyp: [lr, wd_coupled, wd_indep, beta1, beta2, eps, bc1, bc2] where
    bc1/bc2 are the Adam bias-correction factors 1/(1-beta^t) computed by
    the coordinator (which owns the step counter and LR schedule).
    """
    from .optim import adamw_update

    tensors = tensor_table(spec)
    n_params = sum(t.size for t in tensors)
    sizes = [t.size for t in tensors]
    # weight decay applies to weights, not to norm gains
    wd_mask = jnp.concatenate(
        [jnp.full((t.size,), 0.0 if t.kind == "norm" else 1.0, jnp.float32)
         for t in tensors]
    )

    def step(state_ext, tokens, scales, lr_scale, hyp, qmask):
        p = jax.lax.slice(state_ext, (0,), (n_params,))
        m = jax.lax.slice(state_ext, (n_params,), (2 * n_params,))
        v = jax.lax.slice(state_ext, (2 * n_params,), (3 * n_params,))
        (loss, acts), grads = jax.value_and_grad(
            lambda fp: loss_fn(spec, fp, tokens, scales, qmask), has_aux=True
        )(p)
        lr_elem = jnp.concatenate(
            [jnp.full((sz,), 1.0, jnp.float32) * lr_scale[i]
             for i, sz in enumerate(sizes)]
        )
        p2, m2, v2 = adamw_update(p, grads, m, v, lr_elem, wd_mask, hyp)
        tail = rms_tail(spec, acts, p, grads)
        return jnp.concatenate([p2, m2, v2, loss[None], tail])

    return step


def make_eval(spec: Spec):
    """evalf(state_ext, tokens, scales, qmask) -> f32[1 + n_rms]
    (validation loss + activation/weight RMS; grad slots zero)."""

    n_params = sum(t.size for t in tensor_table(spec))

    def evalf(state_ext, tokens, scales, qmask):
        p = jax.lax.slice(state_ext, (0,), (n_params,))
        loss, acts = loss_fn(spec, p, tokens, scales, qmask)
        tail = rms_tail(spec, acts, p, None)
        return jnp.concatenate([loss[None], tail])

    return evalf
