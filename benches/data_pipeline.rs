//! Bench: data substrate off the hot loop — corpus generation and batch
//! sampling must be negligible next to a train step.

use umup::data::{BatchSampler, Corpus, CorpusConfig};
use umup::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::default();
    b.budget = std::time::Duration::from_millis(1200);
    b.run_with_work("corpus generate 200k tokens", Some(200_000.0), &mut || {
        black_box(Corpus::generate(CorpusConfig {
            n_tokens: 200_000,
            ..Default::default()
        }));
    });
    let corpus = Corpus::generate(CorpusConfig::default());
    let mut sampler = BatchSampler::new(corpus.train_slice(), 16, 64, 1);
    b.run_with_work("batch sample 16x65", Some((16 * 65) as f64), &mut || {
        black_box(sampler.sample());
    });
    b.run_with_work("batch sequential 16x65", Some((16 * 65) as f64), &mut || {
        black_box(sampler.next_sequential());
    });
    // the zero-alloc path the train loop actually runs
    let mut buf: Vec<i32> = Vec::new();
    b.run_with_work("batch sample_into 16x65 (reused buf)", Some((16 * 65) as f64), &mut || {
        sampler.sample_into(&mut buf);
        black_box(buf.len());
    });
    b.run_with_work(
        "batch sequential_into 16x65 (reused buf)",
        Some((16 * 65) as f64),
        &mut || {
            sampler.next_sequential_into(&mut buf);
            black_box(buf.len());
        },
    );
    b.run("bigram entropy 2M tokens", || {
        black_box(corpus.bigram_entropy());
    });
}
