//! Bench: data substrate off the hot loop — corpus generation and batch
//! sampling must be negligible next to a train step — with a recorded
//! trajectory.
//!
//! Only within-run *ratios* are gated (the zero-alloc `_into` samplers
//! vs their allocating counterparts) — absolute wall-clock numbers vary
//! too much across runner hardware to compare between machines.
//!
//! Flags (after `cargo bench --bench data_pipeline --`):
//!   --quick           smaller corpus + tighter budgets (CI mode)
//!   --record <path>   append this run's metrics to the trajectory file
//!   --check <path>    gate the ratio metrics against the file's most
//!                     recent entry (>30% regression fails)
//!   --label <name>    entry label for --record (default "dev")

use std::path::PathBuf;
use std::time::Duration;

use umup::data::{BatchSampler, Corpus, CorpusConfig};
use umup::util::bench::{black_box, check_regression, record_run, Bencher, Metric};

fn main() {
    let mut quick = false;
    let mut record: Option<PathBuf> = None;
    let mut check: Option<PathBuf> = None;
    let mut label = "dev".to_string();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--quick" => quick = true,
            "--record" => record = Some(PathBuf::from(it.next().expect("--record needs a path"))),
            "--check" => check = Some(PathBuf::from(it.next().expect("--check needs a path"))),
            "--label" => label = it.next().expect("--label needs a name"),
            // cargo's own bench-harness flags; harmless to ignore
            "--bench" => {}
            other => eprintln!("data_pipeline bench: ignoring unknown arg {other:?}"),
        }
    }

    let mut b = Bencher::default();
    b.budget = Duration::from_millis(if quick { 400 } else { 1200 });
    let gen = b.run_with_work("corpus generate 200k tokens", Some(200_000.0), &mut || {
        black_box(Corpus::generate(CorpusConfig {
            n_tokens: 200_000,
            ..Default::default()
        }));
    });
    let corpus = Corpus::generate(if quick {
        CorpusConfig { n_tokens: 200_000, ..Default::default() }
    } else {
        CorpusConfig::default()
    });
    let mut sampler = BatchSampler::new(corpus.train_slice(), 16, 64, 1);
    let sample = b.run_with_work("batch sample 16x65", Some((16 * 65) as f64), &mut || {
        black_box(sampler.sample());
    });
    let sequential =
        b.run_with_work("batch sequential 16x65", Some((16 * 65) as f64), &mut || {
            black_box(sampler.next_sequential());
        });
    // the zero-alloc paths the train loop actually runs
    let mut buf: Vec<i32> = Vec::new();
    let sample_into = b.run_with_work(
        "batch sample_into 16x65 (reused buf)",
        Some((16 * 65) as f64),
        &mut || {
            sampler.sample_into(&mut buf);
            black_box(buf.len());
        },
    );
    let sequential_into = b.run_with_work(
        "batch sequential_into 16x65 (reused buf)",
        Some((16 * 65) as f64),
        &mut || {
            sampler.next_sequential_into(&mut buf);
            black_box(buf.len());
        },
    );
    b.run("bigram entropy", || {
        black_box(corpus.bigram_entropy());
    });

    let sample_into_speedup = sample.mean_ns / sample_into.mean_ns.max(1.0);
    let sequential_into_speedup = sequential.mean_ns / sequential_into.mean_ns.max(1.0);
    println!(
        "  -> zero-alloc sampling is {sample_into_speedup:.2}x (random) / \
         {sequential_into_speedup:.2}x (sequential) the allocating path"
    );
    let metrics = vec![
        Metric::higher("sample_into_speedup", sample_into_speedup, "x").gated(),
        Metric::higher("sequential_into_speedup", sequential_into_speedup, "x").gated(),
        Metric::higher("corpus_tokens_per_s", 200_000.0 * 1e9 / gen.mean_ns.max(1.0), "1/s"),
        Metric::higher(
            "sample_tokens_per_s",
            (16 * 65) as f64 * 1e9 / sample_into.mean_ns.max(1.0),
            "1/s",
        ),
    ];
    if let Some(path) = &check {
        check_regression(path, "data_pipeline", &metrics, 0.30)
            .expect("bench regression gate");
    }
    if let Some(path) = &record {
        record_run(path, "data_pipeline", &label, &metrics)
            .expect("recording bench trajectory");
    }
}
