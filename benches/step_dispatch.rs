//! Bench: L3 runtime hot path — per-step dispatch cost vs model size.
//!
//! Measures the full Session::step (token upload + execute_b chain +
//! telemetry-tail fetch) and its non-compute floor (tail fetch alone), to
//! verify the coordinator is not the bottleneck (DESIGN.md §9 L3 target:
//! dispatch <5% of step compute at width 256).

use std::path::Path;
use std::sync::Arc;

use umup::engine::{Engine, EngineConfig};
use umup::parametrization::{HpSet, Parametrization, Precision, RuntimeVectors, Scheme};
use umup::runtime::Manifest;
use umup::train::AdamConfig;
use umup::util::bench::Bencher;
use umup::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut bench = Bencher::default();
    bench.budget = std::time::Duration::from_millis(1200);
    bench.min_samples = 5;
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() })?;
    let only = std::env::var("UMUP_BENCH_ONLY").ok();
    // w256 is opt-in (UMUP_BENCH_ONLY=w256): ~2s/step on a 1-core testbed
    for name in ["w32_d4_b16_t64_v256", "w64_d4_b16_t64_v256", "w128_d4_b16_t64_v256"] {
        if let Some(o) = &only {
            if !name.starts_with(o.as_str()) {
                continue;
            }
        }
        let man = Arc::new(Manifest::load(&root.join(name))?);
        let session = engine.session(&man)?;
        for precision in [Precision::Fp32, Precision::Fp8Naive] {
            let vecs = RuntimeVectors::build(
                &man,
                &Parametrization::new(Scheme::Umup),
                &HpSet::with_eta(0.5),
                precision,
            )?;
            let mut ts =
                session.init(0, &vecs.init_std, &vecs.scales, &vecs.lr_scale, &vecs.qmask)?;
            let mut rng = Rng::new(3);
            let tokens: Vec<i32> = (0..man.spec.batch * (man.spec.seq + 1))
                .map(|_| rng.below(man.spec.vocab) as i32)
                .collect();
            let hyp = AdamConfig::default().hyp(0.25, 1);
            let tokens_per_step = (man.spec.batch * man.spec.seq) as f64;
            bench.run_with_work(
                &format!("step+tail {} {}", name, precision.name()),
                Some(tokens_per_step),
                &mut || {
                    session.step(&mut ts, &tokens, &hyp).unwrap();
                },
            );
            bench.run_with_work(
                &format!("step chain-only {} {}", name, precision.name()),
                Some(tokens_per_step),
                &mut || {
                    session.step_chain(&mut ts, &tokens, &hyp).unwrap();
                },
            );
        }
        // eval pass for comparison (fwd only)
        let vecs = RuntimeVectors::build(
            &man,
            &Parametrization::new(Scheme::Umup),
            &HpSet::with_eta(0.5),
            Precision::Fp32,
        )?;
        let ts = session.init(0, &vecs.init_std, &vecs.scales, &vecs.lr_scale, &vecs.qmask)?;
        let mut rng = Rng::new(3);
        let tokens: Vec<i32> = (0..man.spec.batch * (man.spec.seq + 1))
            .map(|_| rng.below(man.spec.vocab) as i32)
            .collect();
        bench.run(&format!("eval {name}"), || {
            session.eval(&ts, &tokens).unwrap();
        });
    }
    Ok(())
}
