//! Bench: L3 runtime hot path — per-step dispatch cost vs model size.
//!
//! Measures the full Session::step (token upload + execute_b chain +
//! telemetry-tail fetch) and its non-compute floor (tail fetch alone), to
//! verify the coordinator is not the bottleneck (DESIGN.md §9 L3 target:
//! dispatch <5% of step compute at width 256).
//!
//! Flags (after `--`):
//!   --quick           w32 manifest only, shorter budget
//!   --record <path>   append this run's metrics to BENCH_step_dispatch.json
//!   --check <path>    gate the tail-overhead ratio against the latest entry
//!   --label <name>    entry label for --record (default "dev")
//!
//! Needs the XLA runtime plus the `artifacts/` manifests (so the gate is
//! not in no-XLA CI).  First baseline on an XLA-equipped machine:
//!   cargo bench --bench step_dispatch -- --record BENCH_step_dispatch.json --label <pr>

use std::path::{Path, PathBuf};
use std::sync::Arc;

use umup::engine::{Engine, EngineConfig};
use umup::parametrization::{HpSet, Parametrization, Precision, RuntimeVectors, Scheme};
use umup::runtime::Manifest;
use umup::train::AdamConfig;
use umup::util::bench::{check_regression, record_run, Bencher, Metric};
use umup::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut quick = false;
    let mut record: Option<PathBuf> = None;
    let mut check: Option<PathBuf> = None;
    let mut label = "dev".to_string();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--quick" => quick = true,
            "--record" => record = Some(PathBuf::from(it.next().expect("--record needs a path"))),
            "--check" => check = Some(PathBuf::from(it.next().expect("--check needs a path"))),
            "--label" => label = it.next().expect("--label needs a name"),
            // cargo's own bench-harness flags; harmless to ignore
            "--bench" => {}
            other => eprintln!("step_dispatch bench: ignoring unknown arg {other:?}"),
        }
    }

    let mut bench = Bencher::default();
    bench.budget = std::time::Duration::from_millis(if quick { 400 } else { 1200 });
    bench.min_samples = 5;
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() })?;
    let only = std::env::var("UMUP_BENCH_ONLY").ok();
    // the trajectory anchors on the smallest manifest: dispatch overhead
    // is most visible where compute is cheapest
    let mut step_w32_fp32 = None;
    let mut chain_w32_fp32 = None;
    let mut step_w32_fp8 = None;
    // w256 is opt-in (UMUP_BENCH_ONLY=w256): ~2s/step on a 1-core testbed
    for name in ["w32_d4_b16_t64_v256", "w64_d4_b16_t64_v256", "w128_d4_b16_t64_v256"] {
        if let Some(o) = &only {
            if !name.starts_with(o.as_str()) {
                continue;
            }
        }
        if quick && !name.starts_with("w32") {
            continue;
        }
        let man = Arc::new(Manifest::load(&root.join(name))?);
        let session = engine.session(&man)?;
        for precision in [Precision::Fp32, Precision::Fp8Naive] {
            let vecs = RuntimeVectors::build(
                &man,
                &Parametrization::new(Scheme::Umup),
                &HpSet::with_eta(0.5),
                precision,
            )?;
            let mut ts =
                session.init(0, &vecs.init_std, &vecs.scales, &vecs.lr_scale, &vecs.qmask)?;
            let mut rng = Rng::new(3);
            let tokens: Vec<i32> = (0..man.spec.batch * (man.spec.seq + 1))
                .map(|_| rng.below(man.spec.vocab) as i32)
                .collect();
            let hyp = AdamConfig::default().hyp(0.25, 1);
            let tokens_per_step = (man.spec.batch * man.spec.seq) as f64;
            let step = bench.run_with_work(
                &format!("step+tail {} {}", name, precision.name()),
                Some(tokens_per_step),
                &mut || {
                    session.step(&mut ts, &tokens, &hyp).unwrap();
                },
            );
            let chain = bench.run_with_work(
                &format!("step chain-only {} {}", name, precision.name()),
                Some(tokens_per_step),
                &mut || {
                    session.step_chain(&mut ts, &tokens, &hyp).unwrap();
                },
            );
            if name.starts_with("w32") {
                match precision {
                    Precision::Fp32 => {
                        step_w32_fp32 = Some(step.mean_ns);
                        chain_w32_fp32 = Some(chain.mean_ns);
                    }
                    _ => step_w32_fp8 = Some(step.mean_ns),
                }
            }
        }
        // eval pass for comparison (fwd only)
        let vecs = RuntimeVectors::build(
            &man,
            &Parametrization::new(Scheme::Umup),
            &HpSet::with_eta(0.5),
            Precision::Fp32,
        )?;
        let ts = session.init(0, &vecs.init_std, &vecs.scales, &vecs.lr_scale, &vecs.qmask)?;
        let mut rng = Rng::new(3);
        let tokens: Vec<i32> = (0..man.spec.batch * (man.spec.seq + 1))
            .map(|_| rng.below(man.spec.vocab) as i32)
            .collect();
        bench.run(&format!("eval {name}"), || {
            session.eval(&ts, &tokens).unwrap();
        });
    }

    // trajectory: absolute step costs for history, plus the gated
    // within-run tail-overhead ratio (step+tail over chain-only — the
    // dispatch + telemetry-fetch multiple the coordinator owns)
    let mut metrics = Vec::new();
    if let (Some(step), Some(chain)) = (step_w32_fp32, chain_w32_fp32) {
        metrics.push(Metric::lower("step_w32_fp32_ns", step, "ns"));
        metrics.push(Metric::lower("chain_w32_fp32_ns", chain, "ns"));
        metrics
            .push(Metric::lower("tail_overhead_w32_fp32_ratio", step / chain.max(1e-9), "x").gated());
    }
    if let Some(step) = step_w32_fp8 {
        metrics.push(Metric::lower("step_w32_fp8_ns", step, "ns"));
    }
    if metrics.is_empty() && (check.is_some() || record.is_some()) {
        println!("note: w32 manifest was filtered out — nothing to record or gate");
    }
    if let Some(path) = &check {
        check_regression(path, "step_dispatch", &metrics, 0.50)?;
    }
    if let Some(path) = &record {
        record_run(path, "step_dispatch", &label, &metrics)?;
    }
    Ok(())
}
