//! Bench: quick-mode end-to-end timings of the per-figure experiment
//! harnesses (one per paper table/figure — reduced sizes so `cargo
//! bench` regenerates every figure's pipeline in minutes).

use std::time::Instant;

use umup::coordinator::{run_experiment, ExpContext};

fn main() -> anyhow::Result<()> {
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/results/bench-quick");
    let ctx = ExpContext::new(artifacts, out, true /* quick */, 4)?;
    // every experiment in quick mode; timings show where the budget goes
    // quick-mode subset (full harnesses: `repro exp all`); one entry per
    // experiment family keeps `cargo bench` minutes-scale on 1 core
    for id in ["tab12", "fig25", "fig6", "fig1c"] {
        let t0 = Instant::now();
        let md = run_experiment(&ctx, id)?;
        println!("{id:6} {:8.2}s  ({} chars of report)", t0.elapsed().as_secs_f64(), md.len());
    }
    Ok(())
}
