//! Bench: run-cache open / refresh / hit costs at sweep scale.
//!
//! The lazy index's contract (see `engine::cache`): cold open scans
//! keys only (no record materialization), a warm no-op
//! `refresh_from_disk` costs a few metadata reads regardless of cache
//! size (the acceptance bar is ≥ 50× faster than a cold open at 100k
//! entries), an incremental refresh costs the bytes actually appended,
//! and hits parse once then serve from the memo.  Runs entirely on the
//! public `RunCache` API, so `--no-default-features` builds it (the
//! `check-no-xla` CI job compiles it via `cargo bench --no-run`).

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use umup::engine::{RunCache, Shard};
use umup::train::RunRecord;
use umup::util::bench::{black_box, Bencher};

fn rec(i: u64) -> RunRecord {
    let loss = 3.0 - (i % 64) as f64 * 0.015625;
    RunRecord {
        label: format!("bench-{i}"),
        // realistic telemetry weight: ~16 curve points per run
        train_curve: (1..=16u64).map(|t| (t * 8, loss + 1.0 / t as f64)).collect(),
        valid_curve: vec![(128, loss)],
        final_valid_loss: loss,
        rms_curves: std::collections::BTreeMap::new(),
        final_rms: vec![("w.head".to_string(), 1.0)],
        diverged: false,
        wall_seconds: 0.5,
    }
}

fn key(i: u64) -> String {
    format!("{i:016x}")
}

/// Build a cache of `n` entries in `dir` (one unsharded segment).
fn build(dir: &Path, n: u64) {
    let mut c = RunCache::open(dir, false).unwrap();
    for i in 0..n {
        c.put(&key(i), "w64_bench", &rec(i)).unwrap();
    }
}

fn bench_at(n: u64) {
    let dir: PathBuf =
        std::env::temp_dir().join(format!("umup-cache-bench-{n}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    build(&dir, n);

    let b = Bencher {
        warmup: Duration::from_millis(50),
        budget: Duration::from_millis(500),
        min_samples: 10,
    };

    // cold open: full key scan of every segment (no record parses)
    let cold = b.run_with_work(&format!("cold open ({n} entries)"), Some(n as f64), &mut || {
        let c = RunCache::open(&dir, true).unwrap();
        black_box(c.len());
    });

    // warm no-op refresh: nothing new on disk — O(segments), not O(n)
    let mut reader = RunCache::open(&dir, true).unwrap();
    let warm =
        b.run_with_work(&format!("warm no-op refresh ({n} entries)"), None, &mut || {
            black_box(reader.refresh_from_disk());
        });
    let speedup = cold.mean_ns / warm.mean_ns.max(1.0);
    println!(
        "  -> warm no-op refresh is {speedup:.0}x faster than cold open \
         (acceptance bar at 100k: >= 50x)"
    );

    // incremental refresh: a sibling shard appends K runs per poll; the
    // reader pays for those K lines, not the n-entry history
    const K: u64 = 16;
    let mut writer =
        RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true).unwrap();
    let mut next = n + 1_000_000;
    let inc = Bencher {
        warmup: Duration::from_millis(20),
        budget: Duration::from_millis(200),
        min_samples: 10,
    };
    inc.run_with_work(
        &format!("incremental refresh, {K} appended ({n} resident)"),
        Some(K as f64),
        &mut || {
            for _ in 0..K {
                writer.put(&key(next), "w64_bench", &rec(next)).unwrap();
                next += 1;
            }
            assert_eq!(reader.refresh_from_disk(), K as usize);
        },
    );
    drop(writer);
    drop(reader);

    // hit lookups: first touch parses one line from its byte span and
    // memoizes; later touches are map reads
    let mut c = RunCache::open(&dir, true).unwrap();
    let t0 = Instant::now();
    for i in 0..n {
        assert!(c.get(&key(i)).is_some());
    }
    let first = t0.elapsed();
    println!(
        "{:44} {n} keys in {first:?} ({:.2} µs/key)",
        format!("hit lookup first-touch ({n} entries)"),
        first.as_secs_f64() * 1e6 / n as f64
    );
    let mut i = 0u64;
    b.run_with_work(&format!("hit lookup memoized ({n} entries)"), None, &mut || {
        black_box(c.get(&key(i % n)).is_some());
        i += 1;
    });

    let _ = std::fs::remove_dir_all(&dir);
}

fn main() {
    for n in [10_000u64, 100_000] {
        bench_at(n);
        println!();
    }
}
