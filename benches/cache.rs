//! Bench: run-cache costs at sweep scale, with a recorded trajectory.
//!
//! Exercises the storage engine's three perf contracts on the public
//! API (see `engine::cache`):
//!
//! - **Streaming gc is bounded by chunk size, not cache size.**  The
//!   compaction pipeline spills key-sorted runs and k-way merges them,
//!   so its memory high-water mark stays O(chunk) even at 10⁶ entries
//!   — asserted here against `VmHWM` in full mode.
//! - **Sidecar adoption beats a scan open.**  A compacted segment
//!   carries a `<segment>.idx` key-presence sidecar; opening against it
//!   validates + adopts instead of scanning every line, and miss-heavy
//!   workloads stop at its bloom filter.
//! - **Warm refresh stays O(segments).**  A no-op `refresh_from_disk`
//!   costs a few metadata reads regardless of resident entries.
//!
//! Runs entirely on pure layers, so `--no-default-features` builds it
//! (CI runs it in `--quick --check` mode and fails on a >30% drop in
//! the gated ratio metrics vs the committed `BENCH_cache.json`).
//!
//! Flags (after `cargo bench --bench cache --`):
//!   --quick           one small size (CI mode) instead of the full
//!                     10k/100k/1M trajectory
//!   --record <path>   append this run's metrics to the trajectory file
//!   --check <path>    gate the ratio metrics against the file's most
//!                     recent entry (>30% regression fails)
//!   --label <name>    entry label for --record (default "dev")
//!
//! Only within-run *ratios* are gated — absolute wall-clock numbers
//! vary too much across runner hardware to compare between machines.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use umup::engine::{gc, GcOptions, RunCache, Shard};
use umup::train::RunRecord;
use umup::util::bench::{black_box, check_regression, record_run, Bencher, Metric};
use umup::util::Json;

fn rec(i: u64) -> RunRecord {
    let loss = 3.0 - (i % 64) as f64 * 0.015625;
    RunRecord {
        label: format!("bench-{i}"),
        // realistic telemetry weight: ~16 curve points per run
        train_curve: (1..=16u64).map(|t| (t * 8, loss + 1.0 / t as f64)).collect(),
        valid_curve: vec![(128, loss)],
        final_valid_loss: loss,
        rms_curves: BTreeMap::new(),
        final_rms: vec![("w.head".to_string(), 1.0)],
        diverged: false,
        wall_seconds: 0.5,
    }
}

fn key(i: u64) -> String {
    format!("{i:016x}")
}

/// One cache line in the canonical sorted-key form (the same shape
/// `RunCache::put` appends; built directly so seeding 10⁶ entries is
/// bounded by disk bandwidth, not by the index bookkeeping under test).
fn line(i: u64) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("key".to_string(), Json::Str(key(i)));
    obj.insert("manifest".to_string(), Json::Str("w64_bench".to_string()));
    obj.insert("record".to_string(), rec(i).to_json());
    obj.insert("ts".to_string(), Json::Num((1_700_000_000 + i) as f64));
    Json::Obj(obj).dump()
}

/// Seed a cache of `n` entries in `dir` as one unsharded segment.
fn build(dir: &Path, n: u64) {
    std::fs::create_dir_all(dir).unwrap();
    let f = std::fs::File::create(dir.join("runs.jsonl")).unwrap();
    let mut w = std::io::BufWriter::new(f);
    for i in 0..n {
        w.write_all(line(i).as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
    }
    w.flush().unwrap();
}

/// Peak resident set (kB) from /proc/self/status, where available.
fn vm_hwm_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for l in status.lines() {
        if let Some(rest) = l.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches("kB").trim().parse().ok();
        }
    }
    None
}

fn segment_bytes(dir: &Path) -> u64 {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.flatten()
                .filter(|e| {
                    e.path().extension().is_some_and(|x| x == "jsonl")
                })
                .filter_map(|e| e.metadata().ok().map(|m| m.len()))
                .sum()
        })
        .unwrap_or(0)
}

/// Time `f` once (for destructive or already-fast-enough-to-not-sample
/// operations) and print a one-line report.
fn once<T>(name: &str, work: f64, f: impl FnOnce() -> T) -> (Duration, T) {
    let t0 = Instant::now();
    let v = f();
    let dt = t0.elapsed();
    println!(
        "{name:44} {dt:>12.3?}  ({:.0} entries/s)",
        work / dt.as_secs_f64().max(1e-9)
    );
    (dt, v)
}

fn bench_at(n: u64, full: bool) -> Vec<Metric> {
    let dir: PathBuf =
        std::env::temp_dir().join(format!("umup-cache-bench-{n}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    build(&dir, n);
    let disk = segment_bytes(&dir);
    println!("== {n} entries, {disk} segment bytes ==");

    // streaming gc: compacts into a key-sorted runs.jsonl + sidecar.
    // Memory is bounded by the spill chunk, not the cache — pinned via
    // the VmHWM delta (gc runs before any index has materialized keys)
    let hwm0 = vm_hwm_kb();
    let (gc_dt, rep) = once(&format!("streaming gc ({n} entries)"), n as f64, || {
        gc(&dir, &GcOptions::default()).unwrap()
    });
    assert_eq!(rep.kept, n as usize);
    let gc_hwm_delta_kb = match (hwm0, vm_hwm_kb()) {
        (Some(a), Some(b)) => {
            let d = b.saturating_sub(a);
            println!("  -> gc VmHWM delta {d} kB over a {disk}-byte cache");
            if full && n >= 1_000_000 {
                assert!(
                    d * 1024 < disk / 4,
                    "streaming gc peak memory ({d} kB) not bounded well below \
                     cache size ({disk} bytes)"
                );
            }
            d as f64
        }
        _ => -1.0,
    };

    let sidecar = dir.join("runs.jsonl.idx");
    assert!(sidecar.exists(), "gc must leave a key-presence sidecar");
    let parked = dir.join("runs.jsonl.idx.parked");

    let b = Bencher {
        warmup: Duration::from_millis(if n >= 1_000_000 { 0 } else { 50 }),
        budget: Duration::from_millis(if n >= 1_000_000 { 1000 } else { 500 }),
        min_samples: if n >= 1_000_000 { 3 } else { 10 },
    };

    // scan open: sidecar parked, every line of every segment is scanned
    std::fs::rename(&sidecar, &parked).unwrap();
    let scan_open =
        b.run_with_work(&format!("scan open, no sidecar ({n})"), Some(n as f64), &mut || {
            let c = RunCache::open(&dir, true).unwrap();
            assert_eq!(c.len(), n as usize);
        });

    // sidecar open: the segment is adopted from its filter instead
    std::fs::rename(&parked, &sidecar).unwrap();
    let sc_open =
        b.run_with_work(&format!("sidecar open ({n})"), Some(n as f64), &mut || {
            let c = RunCache::open(&dir, true).unwrap();
            assert_eq!(c.len(), n as usize);
        });
    let sidecar_open_speedup = scan_open.mean_ns / sc_open.mean_ns.max(1.0);
    println!("  -> sidecar open is {sidecar_open_speedup:.0}x faster than a scan open");

    // miss-heavy workload: open + M absent-key probes.  With the
    // sidecar the probes stop at its bloom filter; without it the open
    // itself pays the full scan.  (Runs before the long-lived reader
    // below exists — an unsharded open holds its segment's lock.)
    const MISSES: u64 = 1000;
    let (t_filtered, _) =
        once(&format!("miss-heavy open+{MISSES} probes, filtered ({n})"), MISSES as f64, || {
            let c = RunCache::open(&dir, true).unwrap();
            for i in 0..MISSES {
                assert!(!c.contains(&key(n + 5_000_000 + i)));
            }
            let fs = c.filter_stats();
            assert!(fs.bloom_rejects > MISSES / 2, "misses should die in the bloom filter");
        });
    std::fs::rename(&sidecar, &parked).unwrap();
    let (t_unfiltered, _) = once(
        &format!("miss-heavy open+{MISSES} probes, unfiltered ({n})"),
        MISSES as f64,
        || {
            let c = RunCache::open(&dir, true).unwrap();
            for i in 0..MISSES {
                assert!(!c.contains(&key(n + 5_000_000 + i)));
            }
        },
    );
    std::fs::rename(&parked, &sidecar).unwrap();
    let missheavy_speedup =
        t_unfiltered.as_secs_f64() / t_filtered.as_secs_f64().max(1e-9);
    println!("  -> filtered miss-heavy workload is {missheavy_speedup:.1}x faster");

    // warm no-op refresh: nothing new on disk — O(segments), not O(n)
    let mut reader = RunCache::open(&dir, true).unwrap();
    assert!(
        reader.filter_stats().segments_skipped >= 1,
        "sidecar open must skip scanning the compacted segment"
    );
    let warm = b.run_with_work(&format!("warm no-op refresh ({n})"), None, &mut || {
        black_box(reader.refresh_from_disk());
    });
    let warm_refresh_speedup = scan_open.mean_ns / warm.mean_ns.max(1.0);
    println!("  -> warm no-op refresh is {warm_refresh_speedup:.0}x faster than a scan open");

    // incremental refresh: a sibling shard appends K runs per poll; the
    // reader pays for those K lines, not the n-entry history
    const K: u64 = 16;
    let mut writer =
        RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true).unwrap();
    let mut next = n + 1_000_000;
    let inc = Bencher {
        warmup: Duration::from_millis(20),
        budget: Duration::from_millis(200),
        min_samples: 10,
    };
    inc.run_with_work(
        &format!("incremental refresh, {K} appended ({n} resident)"),
        Some(K as f64),
        &mut || {
            for _ in 0..K {
                writer.put(&key(next), "w64_bench", &rec(next)).unwrap();
                next += 1;
            }
            assert_eq!(reader.refresh_from_disk(), K as usize);
        },
    );
    drop(writer);
    drop(reader);

    // hit lookups: first touch parses one line from its indexed byte
    // span (resolved through the sidecar) and memoizes; later touches
    // are map reads
    let mut c = RunCache::open(&dir, true).unwrap();
    let t0 = Instant::now();
    for i in 0..n {
        assert!(c.get(&key(i)).is_some());
    }
    let first = t0.elapsed();
    println!(
        "{:44} {n} keys in {first:?} ({:.2} µs/key)",
        format!("hit lookup first-touch ({n} entries)"),
        first.as_secs_f64() * 1e6 / n as f64
    );
    let mut i = 0u64;
    b.run_with_work(&format!("hit lookup memoized ({n} entries)"), None, &mut || {
        black_box(c.get(&key(i % n)).is_some());
        i += 1;
    });
    drop(c);

    let _ = std::fs::remove_dir_all(&dir);

    vec![
        Metric::higher("warm_refresh_speedup", warm_refresh_speedup, "x").gated(),
        Metric::higher("sidecar_open_speedup", sidecar_open_speedup, "x").gated(),
        Metric::higher("missheavy_speedup", missheavy_speedup, "x").gated(),
        Metric::higher("gc_entries_per_s", n as f64 / gc_dt.as_secs_f64().max(1e-9), "1/s"),
        Metric::higher(
            "scan_open_entries_per_s",
            n as f64 * 1e9 / scan_open.mean_ns.max(1.0),
            "1/s",
        ),
        Metric::lower("gc_vmhwm_delta_kb", gc_hwm_delta_kb, "kB"),
        Metric::lower("entries", n as f64, ""),
    ]
}

fn main() {
    let mut quick = false;
    let mut record: Option<PathBuf> = None;
    let mut check: Option<PathBuf> = None;
    let mut label = "dev".to_string();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--quick" => quick = true,
            "--record" => record = Some(PathBuf::from(it.next().expect("--record needs a path"))),
            "--check" => check = Some(PathBuf::from(it.next().expect("--check needs a path"))),
            "--label" => label = it.next().expect("--label needs a name"),
            // cargo's own bench-harness flags; harmless to ignore
            "--bench" => {}
            other => eprintln!("cache bench: ignoring unknown arg {other:?}"),
        }
    }

    let sizes: &[u64] = if quick { &[20_000] } else { &[10_000, 100_000, 1_000_000] };
    let mut last = Vec::new();
    for &n in sizes {
        last = bench_at(n, !quick);
        println!();
    }

    // record/gate the metrics of the largest size benched this run
    if let Some(path) = &check {
        check_regression(path, "cache", &last, 0.30).expect("bench regression gate");
    }
    if let Some(path) = &record {
        record_run(path, "cache", &label, &last).expect("recording bench trajectory");
    }
}
