//! Bench — paper Fig 24 analogue: square matmul throughput with and
//! without Unit Scaling's static output scale, across simulated dtypes.
//!
//! The paper's claim is that a *static* scale adds ~no overhead compared
//! with the matmul itself (unlike amax-based dynamic scaling, which must
//! scan the tensor first).  We measure: plain f32 matmul, scaled matmul,
//! matmul + amax scan (Transformer-Engine-style dynamic scaling cost),
//! and matmul with FP8-sim quantized inputs.

use umup::formats::E4M3;
use umup::util::bench::{black_box, Bencher};
use umup::util::Rng;

fn matmul(a: &[f32], b: &[f32], c: &mut [f32], n: usize, scale: f32) {
    // blocked triple loop (the bench compares *relative* overheads, so a
    // consistent kernel is what matters, not absolute GEMM peak)
    const BS: usize = 64;
    c.iter_mut().for_each(|x| *x = 0.0);
    for ii in (0..n).step_by(BS) {
        for kk in (0..n).step_by(BS) {
            for i in ii..(ii + BS).min(n) {
                for k in kk..(kk + BS).min(n) {
                    let aik = a[i * n + k];
                    let (crow, brow) = (&mut c[i * n..(i + 1) * n], &b[k * n..(k + 1) * n]);
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
    if scale != 1.0 {
        c.iter_mut().for_each(|x| *x *= scale);
    }
}

fn main() {
    let mut bench = Bencher::default();
    bench.budget = std::time::Duration::from_millis(1500);
    bench.min_samples = 5;
    let mut rng = Rng::new(7);
    for n in [256usize, 512] {
        let flops = 2.0 * (n as f64).powi(3);
        let a: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
        let mut c = vec![0f32; n * n];
        println!("\n== {n}x{n} matmul ({:.1} MFLOP) ==", flops / 1e6);
        let base = bench.run_with_work(&format!("f32 unscaled {n}"), Some(flops), &mut || {
            matmul(&a, &b, &mut c, n, 1.0);
            black_box(&c);
        });
        let scaled = bench.run_with_work(&format!("f32 + static scale {n}"), Some(flops), &mut || {
            matmul(&a, &b, &mut c, n, 0.0625);
            black_box(&c);
        });
        let dynamic = bench.run_with_work(
            &format!("f32 + amax dynamic scale {n}"),
            Some(flops),
            &mut || {
                // Transformer-Engine style: scan for amax, scale inputs
                let amax_a = a.iter().fold(0f32, |m, x| m.max(x.abs()));
                let amax_b = b.iter().fold(0f32, |m, x| m.max(x.abs()));
                matmul(&a, &b, &mut c, n, 448.0 / (amax_a * amax_b));
                black_box(&c);
            },
        );
        let mut aq = a.clone();
        let mut bq = b.clone();
        let quant = bench.run_with_work(
            &format!("fp8-sim quantized inputs {n}"),
            Some(flops),
            &mut || {
                aq.copy_from_slice(&a);
                bq.copy_from_slice(&b);
                E4M3.quantize_slice(&mut aq);
                E4M3.quantize_slice(&mut bq);
                matmul(&aq, &bq, &mut c, n, 0.0625);
                black_box(&c);
            },
        );
        println!(
            "   static-scale overhead {:+.1}% | dynamic amax {:+.1}% | quantize {:+.1}%",
            (scaled.mean_ns / base.mean_ns - 1.0) * 100.0,
            (dynamic.mean_ns / base.mean_ns - 1.0) * 100.0,
            (quant.mean_ns / base.mean_ns - 1.0) * 100.0,
        );
    }
    println!("\nPaper Fig 24 shape: static scaling ≈ free; dynamic amax costs extra passes.");
}
