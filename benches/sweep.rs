//! Bench — Fig 1(a) machinery: sweep scheduler scaling and the cost of
//! the search bookkeeping itself (sampling, subset simulation, transfer
//! error) relative to the runs it schedules.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use umup::data::{Corpus, CorpusConfig};
use umup::parametrization::{HpSet, Parametrization, Scheme};
use umup::runtime::Manifest;
use umup::sweep::{run_all_parallel, transfer_error, PairGrid, SweepJob};
use umup::train::{RunConfig, Schedule};
use umup::util::bench::{black_box, Bencher};

fn main() -> anyhow::Result<()> {
    let b = Bencher::default();
    // pure bookkeeping costs
    let grid = PairGrid {
        fixed_name: "a".into(),
        transfer_name: "b".into(),
        fixed_vals: (0..9).map(|i| i as f64).collect(),
        transfer_vals: (0..9).map(|i| i as f64).collect(),
        loss: (0..9).map(|i| (0..9).map(|j| ((i * j) as f64).sin() + 2.0).collect()).collect(),
    };
    b.run("transfer_error 9x9", || {
        black_box(transfer_error(&grid));
    });
    let fake: Vec<f64> = (0..300).map(|i| 2.0 + (i as f64 * 0.77).sin()).collect();
    b.run("simulate_run_counts 300 runs", || {
        // reuse transfer grid losses as stand-in results is not possible
        // without SweepResult; measure the subset sampler via stats path
        black_box(umup::util::stats::percentile(&fake, 10.0));
    });

    // scheduler scaling: real tiny runs, 1 vs 4 workers
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let man = Arc::new(Manifest::load(&root.join("w32_d2_b4_t16_v64"))?);
    let corpus = Corpus::generate(CorpusConfig {
        vocab: man.spec.vocab,
        n_tokens: 120_000,
        ..Default::default()
    });
    let jobs: Vec<SweepJob> = (0..8)
        .map(|i| {
            let eta = 2f64.powf(-2.0 + i as f64 * 0.5);
            let mut cfg = RunConfig::quick(
                &format!("bench-{i}"),
                Parametrization::new(Scheme::Umup),
                HpSet::with_eta(eta),
                16,
            );
            cfg.schedule = Schedule::standard(eta, 16, 4);
            SweepJob { config: cfg, tag: vec![] }
        })
        .collect();
    for workers in [1usize, 2, 4] {
        let t0 = Instant::now();
        let res = run_all_parallel(man.clone(), &corpus, &jobs, workers)?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "scheduler: 8 runs x 16 steps, workers={workers}: {dt:.2}s ({} results)",
            res.len()
        );
    }
    println!("note: ideal scaling is sub-linear — XLA already multithreads each step");
    Ok(())
}
