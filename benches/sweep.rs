//! Bench — Fig 1(a) machinery: engine scaling, the warm-vs-cold engine
//! contrast (compile amortization + run-cache wins), the cost of the
//! search bookkeeping itself (sampling, subset simulation, transfer
//! error) relative to the runs it schedules, and the IPC overhead of
//! the out-of-process backends — pipe vs loopback socket vs in-process,
//! lockstep vs windowed (pipelined) network dispatch.
//!
//! The IPC section runs on mock fixtures and `--mock` workers, so it
//! needs neither XLA nor compiled artifacts: it is the part that runs
//! under `--no-default-features` (and in the CI quick gate).  The
//! XLA-backed sections (engine scaling, warm-vs-cold) need the runtime
//! plus `artifacts/w32_d2_b4_t16_v64`, and are skipped under `--quick`.
//!
//! Flags (after `--`):
//!   --quick             IPC + bookkeeping only (the CI gate mode)
//!   --pipeline-depth N  in-flight window for the pipelined network
//!                       measurement (default 4; 1 collapses it onto
//!                       the lockstep path)
//!   --record <path>     append this run's metrics to BENCH_sweep.json
//!   --check <path>      gate the ratio metrics against the latest entry
//!   --label <name>      entry label for --record (default "dev")
//!
//! First baseline on a toolchain-equipped machine (record the lockstep
//! world and the pipelined world as two labeled entries):
//!   git stash / checkout the pre-pipelining rev, then
//!     cargo bench --bench sweep -- --record BENCH_sweep.json --label pre-pipelining
//!   back on this rev:
//!     cargo bench --bench sweep -- --record BENCH_sweep.json --label pipelined

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use umup::data::{Corpus, CorpusConfig};
use umup::engine::{
    Backend, Engine, EngineConfig, EngineJob, MockBackend, NetworkBackend, ProcessBackend,
};
use umup::parametrization::{HpSet, Parametrization, Scheme};
use umup::runtime::{Manifest, Spec};
use umup::sweep::{transfer_error, PairGrid};
use umup::train::RunConfig;
use umup::util::bench::{black_box, check_regression, record_run, Bencher, Metric};

/// One `repro worker --mock --listen 127.0.0.1:0` child; returns it
/// with the `listening <addr>` announcement read back off its stdout.
fn spawn_listen_worker(exe: &str) -> anyhow::Result<(Child, String)> {
    let mut child = Command::new(exe)
        .arg("worker")
        .arg("--mock")
        .arg("--listen")
        .arg("127.0.0.1:0")
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()?;
    let stdout = child.stdout.take().expect("worker stdout is piped");
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line)?;
    let addr = line
        .strip_prefix("listening ")
        .ok_or_else(|| anyhow::anyhow!("unexpected worker announcement {line:?}"))?
        .trim()
        .to_string();
    Ok((child, addr))
}

/// The IPC section's manifest: metadata only (same shape as the
/// `tests/common` fixtures) — the mock workers never touch tensors, so
/// no compiled artifact is needed and the section runs without XLA.
fn dummy_manifest() -> Arc<Manifest> {
    Arc::new(Manifest {
        name: "w32_sweep_bench".to_string(),
        dir: PathBuf::from("."),
        spec: Spec {
            width: 32,
            depth: 2,
            batch: 4,
            seq: 16,
            vocab: 64,
            head_dim: 16,
            trainable_norms: false,
        },
        tensors: vec![],
        n_params: 0,
        state_ext_len: 1,
        loss_offset: 0,
        rms_offset: 1,
        scale_sites: std::collections::BTreeMap::new(),
        n_scale_sites: 0,
        quant_sites: std::collections::BTreeMap::new(),
        n_quant_sites: 0,
        rms_sites: vec![],
    })
}

fn dummy_corpus() -> Arc<Corpus> {
    Arc::new(Corpus {
        config: CorpusConfig { vocab: 64, n_tokens: 0, ..Default::default() },
        tokens: vec![],
        n_train: 0,
    })
}

/// Engine scaling + warm-vs-cold: real tiny runs through compiled
/// sessions.  Needs the XLA runtime and `artifacts/w32_d2_b4_t16_v64`.
#[cfg(feature = "xla")]
fn xla_sections() -> anyhow::Result<()> {
    use umup::sweep::SweepJob;
    use umup::train::Schedule;

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let man = Arc::new(Manifest::load(&root.join("w32_d2_b4_t16_v64"))?);
    let corpus = Arc::new(Corpus::generate(CorpusConfig {
        vocab: man.spec.vocab,
        n_tokens: 120_000,
        ..Default::default()
    }));
    let jobs: Vec<SweepJob> = (0..8)
        .map(|i| {
            let eta = 2f64.powf(-2.0 + i as f64 * 0.5);
            let mut cfg = RunConfig::quick(
                &format!("bench-{i}"),
                Parametrization::new(Scheme::Umup),
                HpSet::with_eta(eta),
                16,
            );
            cfg.schedule = Schedule::standard(eta, 16, 4);
            SweepJob { config: cfg, tag: vec![] }
        })
        .collect();

    // engine scaling: real tiny runs, 1 vs 4 workers (fresh engine each,
    // so every data point pays its own compiles).  Submission is
    // non-blocking, so the handle also measures streaming latency: how
    // long until the *first* outcome lands vs the whole batch.
    for workers in [1usize, 2, 4] {
        let engine = Engine::new(EngineConfig { workers, ..EngineConfig::default() })?;
        let engine_jobs: Vec<EngineJob> = jobs
            .iter()
            .map(|j| {
                EngineJob::new(
                    Arc::clone(&man),
                    Arc::clone(&corpus),
                    j.config.clone(),
                    j.tag.clone(),
                )
            })
            .collect();
        let t0 = Instant::now();
        let mut handle = engine.submit(engine_jobs);
        let mut first = f64::NAN;
        let mut n = 0usize;
        while let Some(o) = handle.recv() {
            assert!(o.outcome.is_ok(), "bench job failed: {:?}", o.outcome.err());
            if n == 0 {
                first = t0.elapsed().as_secs_f64();
            }
            n += 1;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "engine: 8 runs x 16 steps, workers={workers}: {dt:.2}s total, \
             first outcome after {first:.2}s ({n} results)"
        );
    }
    println!("note: ideal scaling is sub-linear — XLA already multithreads each step");

    // warm vs cold: the engine's two amortization layers.
    //   cold   = fresh engine, empty cache: pays compiles + all runs
    //   warm   = same engine, same jobs: pooled sessions + run-cache hits
    //   resume = new engine reading the persisted cache (simulated
    //            process restart): no runs, no compiles
    let cache_dir = std::env::temp_dir().join(format!("umup-sweep-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    let engine_jobs = |man: &Arc<Manifest>, corpus: &Arc<Corpus>| -> Vec<EngineJob> {
        jobs.iter()
            .map(|j| {
                EngineJob::new(Arc::clone(man), Arc::clone(corpus), j.config.clone(), j.tag.clone())
            })
            .collect()
    };
    let engine = Engine::new(EngineConfig {
        workers: 2,
        cache_dir: Some(cache_dir.clone()),
        ..EngineConfig::default()
    })?;
    let t0 = Instant::now();
    engine.submit(engine_jobs(&man, &corpus)).wait().into_sweep_results()?;
    let cold = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    engine.submit(engine_jobs(&man, &corpus)).wait().into_sweep_results()?;
    let warm = t0.elapsed().as_secs_f64();
    let s = engine.stats();
    assert_eq!(s.executed, jobs.len(), "warm pass must not re-run jobs");
    assert_eq!(s.cache_hits, jobs.len());
    drop(engine);
    let engine = Engine::new(EngineConfig {
        workers: 2,
        cache_dir: Some(cache_dir.clone()),
        resume: true,
        ..EngineConfig::default()
    })?;
    let t0 = Instant::now();
    engine.submit(engine_jobs(&man, &corpus)).wait().into_sweep_results()?;
    let resume = t0.elapsed().as_secs_f64();
    assert_eq!(engine.stats().executed, 0, "resume pass must come entirely from disk");
    println!(
        "engine warm-vs-cold (8 jobs): cold {cold:.2}s  warm {:.0}x faster ({warm:.4}s)  \
         resume-from-disk {:.0}x faster ({resume:.4}s)",
        cold / warm.max(1e-9),
        cold / resume.max(1e-9),
    );
    let _ = std::fs::remove_dir_all(&cache_dir);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut quick = false;
    let mut depth = 4usize;
    let mut record: Option<PathBuf> = None;
    let mut check: Option<PathBuf> = None;
    let mut label = "dev".to_string();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--quick" => quick = true,
            "--pipeline-depth" => {
                depth = it
                    .next()
                    .expect("--pipeline-depth needs a value")
                    .parse()
                    .expect("bad --pipeline-depth");
            }
            "--record" => record = Some(PathBuf::from(it.next().expect("--record needs a path"))),
            "--check" => check = Some(PathBuf::from(it.next().expect("--check needs a path"))),
            "--label" => label = it.next().expect("--label needs a name"),
            // cargo's own bench-harness flags; harmless to ignore
            "--bench" => {}
            other => eprintln!("sweep bench: ignoring unknown arg {other:?}"),
        }
    }

    let b = Bencher::default();
    // pure bookkeeping costs
    let grid = PairGrid {
        fixed_name: "a".into(),
        transfer_name: "b".into(),
        fixed_vals: (0..9).map(|i| i as f64).collect(),
        transfer_vals: (0..9).map(|i| i as f64).collect(),
        loss: (0..9).map(|i| (0..9).map(|j| ((i * j) as f64).sin() + 2.0).collect()).collect(),
    };
    b.run("transfer_error 9x9", || {
        black_box(transfer_error(&grid));
    });
    let fake: Vec<f64> = (0..300).map(|i| 2.0 + (i as f64 * 0.77).sin()).collect();
    b.run("simulate_run_counts 300 runs", || {
        // reuse transfer grid losses as stand-in results is not possible
        // without SweepResult; measure the subset sampler via stats path
        black_box(umup::util::stats::percentile(&fake, 10.0));
    });

    if quick {
        println!("--quick: skipping XLA engine-scaling + warm-vs-cold sections");
    } else {
        #[cfg(feature = "xla")]
        xla_sections()?;
        #[cfg(not(feature = "xla"))]
        println!("no-XLA build: skipping engine-scaling + warm-vs-cold sections");
    }

    // IPC overhead of the out-of-process backends, isolated from
    // training cost: the same no-op sweep on the in-process
    // deterministic mock vs 4 `repro worker --mock` children (pipes) vs
    // 4 `repro worker --mock --listen` endpoints (loopback TCP), the
    // latter both in lockstep (depth 1) and windowed (--pipeline-depth)
    // dispatch.  The per-job deltas are pure spawn/dial + wire/framing +
    // codec cost — and the lockstep-vs-pipelined delta is the round-trip
    // stall the in-flight window exists to hide — tracked so the backend
    // layer shows up in the perf trajectory.
    let man = dummy_manifest();
    let corpus = dummy_corpus();
    let n_ipc_jobs = 64usize;
    let ipc_jobs = || -> Vec<EngineJob> {
        (0..n_ipc_jobs)
            .map(|i| {
                let eta = 0.015625 * (i + 1) as f64;
                EngineJob::new(
                    Arc::clone(&man),
                    Arc::clone(&corpus),
                    RunConfig::quick(
                        &format!("ipc-{i}"),
                        Parametrization::new(Scheme::Umup),
                        HpSet::with_eta(eta),
                        8,
                    ),
                    vec![],
                )
            })
            .collect()
    };
    let worker_exe = env!("CARGO_BIN_EXE_repro").to_string();
    let mut fleet = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..4 {
        let (child, addr) = spawn_listen_worker(&worker_exe)?;
        fleet.push(child);
        addrs.push(addr);
    }
    let pipe_exe = worker_exe.clone();
    let backends: Vec<(String, &str, Arc<dyn Backend>)> = vec![
        ("in-process mock".to_string(), "inprocess", Arc::new(MockBackend::deterministic())),
        (
            "process mock (4 children)".to_string(),
            "process",
            Arc::new(ProcessBackend::new(move |_worker| {
                let mut cmd = Command::new(&pipe_exe);
                cmd.arg("worker").arg("--mock");
                cmd
            })),
        ),
        (
            "network mock (4 listeners, lockstep)".to_string(),
            "network_d1",
            Arc::new(NetworkBackend::new(&addrs.join(","))?.with_pipeline_depth(1)),
        ),
        (
            format!("network mock (4 listeners, window {depth})"),
            "network_pipelined",
            Arc::new(NetworkBackend::new(&addrs.join(","))?.with_pipeline_depth(depth)),
        ),
        (
            format!("network mock (4 listeners, window {depth}, 30s job deadline)"),
            "network_deadline",
            Arc::new(
                NetworkBackend::new(&addrs.join(","))?
                    .with_pipeline_depth(depth)
                    .with_job_timeout(Some(Duration::from_secs(30))),
            ),
        ),
    ];
    let mut per_job_ms = std::collections::BTreeMap::new();
    for (name, key, backend) in backends {
        let engine =
            Engine::with_backend(EngineConfig { workers: 4, ..EngineConfig::default() }, backend)?;
        let t0 = Instant::now();
        let mut handle = engine.submit(ipc_jobs());
        let mut first = f64::NAN;
        let mut n = 0usize;
        while let Some(o) = handle.recv() {
            assert!(o.outcome.is_ok(), "ipc bench job failed: {:?}", o.outcome.err());
            if n == 0 {
                first = t0.elapsed().as_secs_f64();
            }
            n += 1;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "backend {name}: {n_ipc_jobs} no-op jobs in {:.1}ms total \
             ({:.2}ms/job), first outcome after {:.1}ms",
            dt * 1e3,
            dt * 1e3 / n_ipc_jobs as f64,
            first * 1e3
        );
        assert_eq!(n, n_ipc_jobs);
        per_job_ms.insert(key, dt * 1e3 / n_ipc_jobs as f64);
    }
    for mut child in fleet {
        let _ = child.kill();
        let _ = child.wait();
    }

    // the trajectory: absolute per-job costs for the history, plus
    // gated within-run ratios (absolute wall-clock varies across runner
    // hardware; the multiples are what the backend layer actually owns).
    // `network_pipelined_vs_lockstep_per_job_ratio` is the pipelining
    // win itself: windowed dispatch over the same sockets, same jobs —
    // below 1.0 means the in-flight window beats lockstep.
    let inproc = per_job_ms["inprocess"];
    let metrics = vec![
        Metric::lower("inprocess_per_job_ms", inproc, "ms"),
        Metric::lower("process_per_job_ms", per_job_ms["process"], "ms"),
        Metric::lower("network_d1_per_job_ms", per_job_ms["network_d1"], "ms"),
        Metric::lower("network_pipelined_per_job_ms", per_job_ms["network_pipelined"], "ms"),
        Metric::lower(
            "process_vs_inprocess_per_job_ratio",
            per_job_ms["process"] / inproc.max(1e-9),
            "x",
        )
        .gated(),
        Metric::lower(
            "network_vs_inprocess_per_job_ratio",
            per_job_ms["network_d1"] / inproc.max(1e-9),
            "x",
        ),
        Metric::lower(
            "network_pipelined_vs_lockstep_per_job_ratio",
            per_job_ms["network_pipelined"] / per_job_ms["network_d1"].max(1e-9),
            "x",
        )
        .gated(),
        Metric::lower("network_deadline_per_job_ms", per_job_ms["network_deadline"], "ms"),
        // the cost of arming --job-timeout: same sockets, same window,
        // but every read sits behind a (never-firing) 30s deadline —
        // gated so a deadline path that starts re-arming timers or
        // copying per frame shows up as a regression here
        Metric::lower(
            "network_deadline_vs_unarmed_per_job_ratio",
            per_job_ms["network_deadline"] / per_job_ms["network_pipelined"].max(1e-9),
            "x",
        )
        .gated(),
    ];
    // wider tolerance than the cache gate: these are ~ms-scale no-op
    // sweeps, so scheduler jitter moves the ratio more than real work
    if let Some(path) = &check {
        check_regression(path, "sweep", &metrics, 0.50)?;
    }
    if let Some(path) = &record {
        record_run(path, "sweep", &label, &metrics)?;
    }
    Ok(())
}
