//! Bench: the two codec hot paths.
//!
//! 1. Software numeric formats (the Rust half of the paper's Appendix K
//!    claim that static-scale quantization is cheap): quantize-slice
//!    throughput per format, RMS stats, scalar latency.
//! 2. The worker wire codec (`engine::backend::wire`): the allocating
//!    encoders vs their `_into` twins that the pipelined dispatch path
//!    reuses caller scratch through — plus a hard steady-state check,
//!    via a counting global allocator, that one full
//!    encode→frame→flush→read→reply cycle performs **zero** heap
//!    allocation once the scratch buffers are warm.
//!
//! Flags (after `--`):
//!   --quick           smaller element counts + shorter budgets (the CI
//!                     gate mode; the zero-alloc check always runs)
//!   --record <path>   append this run's metrics to BENCH_codec.json
//!   --check <path>    gate the gated metrics against the latest entry
//!   --label <name>    entry label for --record (default "dev")
//!
//! First baseline on a toolchain-equipped machine:
//!   cargo bench --bench codec --no-default-features -- --record BENCH_codec.json --label <pr>

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use umup::data::{Corpus, CorpusConfig};
use umup::engine::backend::wire;
use umup::engine::{det_record, EngineJob};
use umup::formats::{TensorStats, BF16, E4M3, E5M2, FP16};
use umup::parametrization::{HpSet, Parametrization, Scheme};
use umup::runtime::{Manifest, Spec};
use umup::train::RunConfig;
use umup::util::bench::{black_box, check_regression, record_run, Bencher, Metric};
use umup::util::Rng;

/// Counts every heap allocation (alloc / alloc_zeroed / realloc) on top
/// of the system allocator, so the zero-alloc claim on the `_into`
/// codec chain is asserted, not eyeballed.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// The same no-XLA fixture shape as `tests/common`: a manifest is its
/// metadata, a corpus is its generator config — all the codec touches.
fn bench_job() -> EngineJob {
    let man = Arc::new(Manifest {
        name: "w32_codec_bench".to_string(),
        dir: PathBuf::from("."),
        spec: Spec {
            width: 32,
            depth: 2,
            batch: 4,
            seq: 16,
            vocab: 64,
            head_dim: 16,
            trainable_norms: false,
        },
        tensors: vec![],
        n_params: 0,
        state_ext_len: 1,
        loss_offset: 0,
        rms_offset: 1,
        scale_sites: std::collections::BTreeMap::new(),
        n_scale_sites: 0,
        quant_sites: std::collections::BTreeMap::new(),
        n_quant_sites: 0,
        rms_sites: vec![],
    });
    let corpus = Arc::new(Corpus {
        config: CorpusConfig { vocab: 64, n_tokens: 120_000, seed: 7, ..Default::default() },
        tokens: vec![],
        n_train: 0,
    });
    let cfg = RunConfig::quick(
        "codec-bench",
        Parametrization::new(Scheme::Umup),
        HpSet::with_eta(0.25),
        16,
    );
    EngineJob::new(man, corpus, cfg, vec![])
}

fn main() -> anyhow::Result<()> {
    let mut quick = false;
    let mut record: Option<PathBuf> = None;
    let mut check: Option<PathBuf> = None;
    let mut label = "dev".to_string();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--quick" => quick = true,
            "--record" => record = Some(PathBuf::from(it.next().expect("--record needs a path"))),
            "--check" => check = Some(PathBuf::from(it.next().expect("--check needs a path"))),
            "--label" => label = it.next().expect("--label needs a name"),
            // cargo's own bench-harness flags; harmless to ignore
            "--bench" => {}
            other => eprintln!("codec bench: ignoring unknown arg {other:?}"),
        }
    }

    let mut b = Bencher::default();
    b.budget = std::time::Duration::from_millis(if quick { 250 } else { 1200 });
    if quick {
        b.warmup = std::time::Duration::from_millis(50);
    }

    // ---- numeric formats -------------------------------------------
    let mut rng = Rng::new(1);
    let n = if quick { 1 << 16 } else { 1 << 20 };
    let xs: Vec<f32> = (0..n).map(|_| (rng.normal() * 3.0) as f32).collect();
    println!("format codec throughput over {n} f32 elements\n");
    let mut e4m3_per_s = f64::NAN;
    for fmt in [E4M3, E5M2, FP16, BF16] {
        let mut buf = xs.clone();
        let r = b.run_with_work(
            &format!("quantize_slice {}", fmt.name),
            Some(n as f64),
            &mut || {
                buf.copy_from_slice(&xs);
                black_box(fmt.quantize_slice(&mut buf));
            },
        );
        if fmt.name == E4M3.name {
            e4m3_per_s = r.throughput().unwrap_or(f64::NAN);
        }
    }
    b.run_with_work("TensorStats::of (RMS)", Some(n as f64), &mut || {
        black_box(TensorStats::of(&xs));
    });
    // scalar quantize latency (used in hot per-site paths)
    b.run("quantize scalar e4m3 x1k", || {
        for i in 0..1000 {
            black_box(E4M3.quantize(xs[i % n]));
        }
    });

    // ---- wire codec: allocating vs `_into` twins -------------------
    println!("\nwire codec (job frame {{encode,frame,read}} + reply lines)\n");
    let job = bench_job();
    let key = job.key();
    let reply_record = det_record(&job.config);

    let enc = b.run("encode_job (fresh String)", || {
        black_box(wire::encode_job(&key, &job));
    });
    let mut payload = String::new();
    let enc_into = b.run("encode_job_into (reused scratch)", || {
        payload.clear();
        wire::encode_job_into(&key, &job, &mut payload);
        black_box(payload.len());
    });

    // one framed ok-reply, read back over and over (a `&[u8]` is a
    // BufRead, so re-slicing it each iteration costs nothing)
    let mut reply_frame = Vec::new();
    wire::write_frame(&mut reply_frame, &wire::ok_reply_line(&key, &job.manifest.name, &reply_record))?;
    let rd = b.run("read_frame (fresh String)", || {
        let mut r: &[u8] = &reply_frame;
        black_box(wire::read_frame(&mut r).unwrap().unwrap().len());
    });
    let mut scratch: Vec<u8> = Vec::new();
    let rd_into = b.run("read_frame_into (reused scratch)", || {
        let mut r: &[u8] = &reply_frame;
        black_box(wire::read_frame_into(&mut r, &mut scratch).unwrap().unwrap().len());
    });

    let ok = b.run("ok_reply_line (fresh String)", || {
        black_box(wire::ok_reply_line(&key, &job.manifest.name, &reply_record).len());
    });
    let mut reply_buf = String::new();
    let ok_into = b.run("ok_reply_line_into (reused scratch)", || {
        reply_buf.clear();
        wire::ok_reply_line_into(&key, &job.manifest.name, &reply_record, &mut reply_buf);
        black_box(reply_buf.len());
    });

    // ---- the zero-alloc steady-state assertion ---------------------
    //
    // One full pipelined-dispatch cycle: encode the job payload, frame
    // it into the batch buffer, ship the batch (into a sink — the
    // transport write itself is the OS's business), read a reply frame
    // back through the scratch buffer, and encode both reply shapes.
    // After warmup (buffers at steady-state capacity) the whole cycle
    // must not touch the heap.  `now_ts()` re-reads UMUP_CACHE_TS per
    // call and the *hit* path materializes a String, so the variable is
    // cleared first — the engine's production hot path runs unpinned.
    std::env::remove_var("UMUP_CACHE_TS");
    let mut batch = String::new();
    let mut sink = std::io::sink();
    let mut cycle = || -> anyhow::Result<()> {
        payload.clear();
        wire::encode_job_into(&key, &job, &mut payload);
        batch.clear();
        wire::frame_into(&payload, &mut batch);
        wire::flush_frames(&mut sink, &batch)?;
        let mut r: &[u8] = &reply_frame;
        let line = wire::read_frame_into(&mut r, &mut scratch)?.expect("prebuilt frame");
        black_box(line.len());
        reply_buf.clear();
        wire::ok_reply_line_into(&key, &job.manifest.name, &reply_record, &mut reply_buf);
        black_box(reply_buf.len());
        reply_buf.clear();
        wire::err_reply_line_into(&key, "injected job failure", &mut reply_buf);
        black_box(reply_buf.len());
        Ok(())
    };
    for _ in 0..100 {
        cycle()?;
    }
    let counted = if quick { 2_000u64 } else { 10_000u64 };
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..counted {
        cycle()?;
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    let allocs_per_frame = delta as f64 / counted as f64;
    println!(
        "\nzero-alloc check: {delta} heap allocations across {counted} warm \
         encode→frame→flush→read→reply cycles ({allocs_per_frame:.4}/cycle)"
    );
    assert_eq!(
        delta, 0,
        "the `_into` codec chain allocated {delta} times in {counted} warm cycles — \
         the zero-realloc hot-path contract is broken"
    );

    // ---- trajectory -------------------------------------------------
    // Absolute ns for history; the gates are the within-run `_into`
    // speedup ratios (hardware-independent) and the alloc count (an
    // exact contract: once 0 is recorded, any allocation regresses).
    let metrics = vec![
        Metric::higher("quantize_e4m3_elem_per_s", e4m3_per_s, "el/s"),
        Metric::lower("encode_job_ns", enc.mean_ns, "ns"),
        Metric::lower("encode_job_into_ns", enc_into.mean_ns, "ns"),
        Metric::lower("read_frame_ns", rd.mean_ns, "ns"),
        Metric::lower("read_frame_into_ns", rd_into.mean_ns, "ns"),
        Metric::lower("ok_reply_ns", ok.mean_ns, "ns"),
        Metric::lower("ok_reply_into_ns", ok_into.mean_ns, "ns"),
        Metric::higher("encode_into_speedup", enc.mean_ns / enc_into.mean_ns.max(1e-9), "x")
            .gated(),
        Metric::higher("read_into_speedup", rd.mean_ns / rd_into.mean_ns.max(1e-9), "x").gated(),
        Metric::lower("wire_into_allocs_per_frame", allocs_per_frame, "allocs").gated(),
    ];
    // µs-scale codec loops jitter more than the cache bench's ms-scale
    // scans; gate with the same wide tolerance as the sweep ratios
    if let Some(path) = &check {
        check_regression(path, "codec", &metrics, 0.50)?;
    }
    if let Some(path) = &record {
        record_run(path, "codec", &label, &metrics)?;
    }
    Ok(())
}
