//! Bench: software numeric-format codec throughput (the Rust half of the
//! paper's Appendix K claim that static-scale quantization is cheap).

use umup::formats::{TensorStats, BF16, E4M3, E5M2, FP16};
use umup::util::bench::{black_box, Bencher};
use umup::util::Rng;

fn main() {
    let mut b = Bencher::default();
    b.budget = std::time::Duration::from_millis(1200);
    let mut rng = Rng::new(1);
    let n = 1 << 20;
    let xs: Vec<f32> = (0..n).map(|_| (rng.normal() * 3.0) as f32).collect();
    println!("codec throughput over {n} f32 elements\n");
    for fmt in [E4M3, E5M2, FP16, BF16] {
        let mut buf = xs.clone();
        b.run_with_work(
            &format!("quantize_slice {}", fmt.name),
            Some(n as f64),
            &mut || {
                buf.copy_from_slice(&xs);
                black_box(fmt.quantize_slice(&mut buf));
            },
        );
    }
    b.run_with_work("TensorStats::of (RMS)", Some(n as f64), &mut || {
        black_box(TensorStats::of(&xs));
    });
    // scalar quantize latency (used in hot per-site paths)
    b.run("quantize scalar e4m3 x1k", || {
        for i in 0..1000 {
            black_box(E4M3.quantize(xs[i]));
        }
    });
}
